//! Integration: Rust runtime vs Python golden traces.
//!
//! The AOT pipeline dumps, per model, a fully-computed 50-step DDIM/RF
//! trajectory (x_T, per-step ε̂, per-step x) plus single-block and head
//! parity points. These tests replay the trajectory through the PJRT
//! runtime + native sampler and require 1e-3 agreement end-to-end — the
//! contract that the HLO-text interchange and the Rust step math are
//! numerically faithful to the Python reference.
//!
//! PJRT-only by construction (it validates artifact execution), so the
//! whole suite is gated on the `pjrt` feature; the native backend's
//! equivalents live in `runtime/native.rs` unit tests and run always.

#![cfg(feature = "pjrt")]

use speca::config::{Manifest, ScheduleKind};
use speca::coordinator::policy::ErrorMetric;
use speca::runtime::{ClassifierRuntime, In, ModelRuntime, Runtime};
use speca::sampler;
use speca::weights::TensorFile;

fn manifest() -> Option<Manifest> {
    let dir = speca::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest loads"))
}

#[test]
fn golden_trajectory_all_models() {
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    for (name, entry) in &manifest.models {
        let model = ModelRuntime::load(&rt, entry).unwrap();
        let g = TensorFile::load(&entry.goldens).unwrap();
        let x_t = g.f32("x_T").unwrap();
        let y = g.i32("y").unwrap().to_vec();
        let eps_all = g.f32("eps_all").unwrap();
        let x_all = g.f32("x_all").unwrap();
        let sched = &entry.schedule;
        let steps = entry.config.serve_steps;

        let mut x = x_t.data.clone();
        for i in 0..steps {
            let t = vec![sched.t_model[i]];
            let (eps, _) = model.full(1, &x, &t, &y, false).unwrap();
            let expect = eps_all.row(i);
            let max_err = eps
                .data
                .iter()
                .zip(expect)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-3, "{name} step {i}: eps err {max_err}");
            match sched.kind {
                ScheduleKind::Ddim => {
                    sampler::ddim_step(&mut x, &eps.data, sched.ab_t[i], sched.ab_prev[i])
                }
                ScheduleKind::RectifiedFlow => sampler::rf_step(&mut x, &eps.data, sched.dt),
            }
            let expect_x = x_all.row(i);
            let max_err = x
                .iter()
                .zip(expect_x)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-2, "{name} step {i}: x err {max_err}");
        }
        println!("{name}: {steps}-step golden trajectory OK");
    }
}

#[test]
fn golden_block_and_head_parity() {
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    for (name, entry) in &manifest.models {
        let model = ModelRuntime::load(&rt, entry).unwrap();
        let g = TensorFile::load(&entry.goldens).unwrap();
        let bounds = g.f32("boundaries0").unwrap(); // [L+1, T, D]
        let v = g.i32("verify_layer").unwrap()[0];
        let y = g.i32("y").unwrap().to_vec();
        let t = vec![entry.schedule.t_model[0]];
        let feat = entry.feat_len();

        let out = model
            .block(1, v, bounds.row(v as usize), &t, &y)
            .unwrap();
        let expect = g.f32("block_out").unwrap();
        let e = ErrorMetric::L2.eval(&out.data, &expect.data);
        assert!(e < 1e-4, "{name}: block rel err {e}");
        // block_fwd(v, boundaries[v]) must equal boundaries[v+1]
        let e2 = ErrorMetric::L2.eval(&out.data, bounds.row(v as usize + 1));
        assert!(e2 < 1e-4, "{name}: block-vs-boundary rel err {e2}");

        let head = model
            .head(1, bounds.row(entry.config.depth), &t, &y)
            .unwrap();
        let expect = g.f32("head_out").unwrap();
        let e = ErrorMetric::L2.eval(&head.data, &expect.data);
        assert!(e < 1e-4, "{name}: head rel err {e}");
        assert_eq!(head.data.len(), entry.config.latent_dim);
        let _ = feat;
    }
}

#[test]
fn kernel_artifacts_match_native() {
    // The standalone Pallas kernel artifacts (taylor predict/update, verify
    // stats, sampler step) must agree with the native Rust hot-path
    // implementations they mirror.
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = manifest.models.values().next().unwrap();
    let model = ModelRuntime::load(&rt, entry).unwrap();
    let feat = entry.feat_len();

    // taylor predict: PJRT kernel vs native TapCache
    let mut cache = speca::cache::TapCache::new(2, feat, 5);
    let mk = |s: u64| -> Vec<f32> {
        let mut rng = speca::util::rng::Rng::new(s);
        rng.normal_f32s(feat)
    };
    let mut factors_flat = Vec::new();
    for s in 0..3u64 {
        cache.refresh(&mk(s));
    }
    for f in cache.factors() {
        factors_flat.extend_from_slice(f);
    }
    let exec = model.kernel_exec("taylor_predict").unwrap();
    let out = exec
        .run(
            &rt,
            &[],
            &[
                In::F32(&factors_flat, &[3, feat]),
                In::ScalarF32(3.0),
                In::ScalarF32(5.0),
            ],
        )
        .unwrap();
    let native = cache.predict(3.0, speca::cache::DraftKind::Taylor);
    let e = ErrorMetric::L2.eval(&out[0].data, &native);
    assert!(e < 1e-5, "taylor_predict kernel vs native: rel err {e}");

    // verify stats kernel vs native metrics
    let a = mk(10);
    let b = mk(11);
    let exec = model.kernel_exec("verify_stats").unwrap();
    let stats = exec
        .run(&rt, &[], &[In::F32(&a, &[feat]), In::F32(&b, &[feat])])
        .unwrap();
    let s = &stats[0].data;
    let rel_l2_kernel = (s[0].sqrt() / (s[1].sqrt() + 1e-8)) as f64;
    let rel_l2_native = ErrorMetric::L2.eval(&a, &b);
    assert!((rel_l2_kernel - rel_l2_native).abs() < 1e-5);
    let rel_l1_kernel = (s[2] / (s[3] + 1e-8)) as f64;
    assert!((rel_l1_kernel - ErrorMetric::L1.eval(&a, &b)).abs() < 1e-5);

    // sampler step kernel vs native
    let latent = entry.config.latent_dim;
    let x = mk(20)[..latent].to_vec();
    let e_in = mk(21)[..latent].to_vec();
    let exec = model.kernel_exec("step").unwrap();
    let (out, mut native) = match entry.config.schedule_kind {
        ScheduleKind::Ddim => {
            let out = exec
                .run(
                    &rt,
                    &[],
                    &[
                        In::F32(&x, &[latent]),
                        In::F32(&e_in, &[latent]),
                        In::ScalarF32(0.5),
                        In::ScalarF32(0.7),
                    ],
                )
                .unwrap();
            let mut n = x.clone();
            sampler::ddim_step(&mut n, &e_in, 0.5, 0.7);
            (out, n)
        }
        ScheduleKind::RectifiedFlow => {
            let out = exec
                .run(
                    &rt,
                    &[],
                    &[
                        In::F32(&x, &[latent]),
                        In::F32(&e_in, &[latent]),
                        In::ScalarF32(0.02),
                    ],
                )
                .unwrap();
            let mut n = x.clone();
            sampler::rf_step(&mut n, &e_in, 0.02);
            (out, n)
        }
    };
    let e = ErrorMetric::L2.eval(&out[0].data, &native);
    assert!(e < 1e-5, "step kernel vs native: rel err {e}");
    native.clear();
}

#[test]
fn classifier_golden_parity() {
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let cls = ClassifierRuntime::load(&rt, &manifest.classifier).unwrap();
    let g = TensorFile::load(&manifest.classifier.goldens).unwrap();
    let x = g.f32("cls_in").unwrap();
    let expect_logits = g.f32("cls_logits").unwrap();
    let expect_feats = g.f32("cls_feats").unwrap();
    let n = x.shape[0];
    for i in 0..n {
        let (logits, feats) = cls.classify(1, x.row(i)).unwrap();
        let e1 = ErrorMetric::L2.eval(&logits.data, expect_logits.row(i));
        let e2 = ErrorMetric::L2.eval(&feats.data, expect_feats.row(i));
        assert!(e1 < 1e-4 && e2 < 1e-4, "sample {i}: {e1} {e2}");
    }
}

#[test]
fn batched_execution_matches_single() {
    // Padded/batched execution must be numerically identical per row to
    // bucket-1 execution (what makes dynamic batching transparent).
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = manifest.models.values().next().unwrap();
    let model = ModelRuntime::load(&rt, entry).unwrap();
    let latent = entry.config.latent_dim;
    let mut rng = speca::util::rng::Rng::new(99);
    let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_f32s(latent)).collect();
    let t: Vec<f32> = (0..4).map(|i| entry.schedule.t_model[i * 3]).collect();
    let y: Vec<i32> = vec![0, 1, 2, 3];

    let mut x4 = Vec::new();
    for r in &rows {
        x4.extend_from_slice(r);
    }
    let (eps4, bounds4) = model.full(4, &x4, &t, &y, false).unwrap();
    for i in 0..4 {
        let (eps1, bounds1) = model
            .full(1, &rows[i], &t[i..i + 1], &y[i..i + 1], false)
            .unwrap();
        let e = ErrorMetric::L2.eval(eps4.row(i), &eps1.data);
        assert!(e < 1e-4, "row {i}: eps rel err {e}");
        // boundary slices: bounds4 is [L+1, 4, T, D]
        let feat = entry.feat_len();
        for b in 0..=entry.config.depth {
            let off4 = (b * 4 + i) * feat;
            let off1 = b * feat;
            let e = ErrorMetric::L2.eval(
                &bounds4.data[off4..off4 + feat],
                &bounds1.data[off1..off1 + feat],
            );
            assert!(e < 1e-4, "row {i} boundary {b}: rel err {e}");
        }
    }
}
