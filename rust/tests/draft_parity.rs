//! Parity suite for the draft-strategy refactor (ISSUE 3 acceptance):
//! the trait-based `reuse` / `adams-bashforth` / `taylor` strategies must
//! be **bitwise identical** to the legacy [`DraftKind`] enum paths — per
//! prediction over fuzzed histories, and end-to-end through the engine
//! (latents + verify traces) — and the two new strategies must be
//! registered and behave per their documented math (DESIGN.md §10).

use speca::cache::{Draft, DraftKind, DraftRegistry, TapCache};
use speca::config::ModelConfig;
use speca::coordinator::policy::{Policy, SpeCaConfig};
use speca::coordinator::state::Completion;
use speca::coordinator::{Engine, EngineConfig};
use speca::runtime::{ModelBackend, NativeBackend};
use speca::util::prop::prop_check;
use speca::util::rng::Rng;
use speca::workload::{batch_requests, parse_policy};

/// Enum ↔ trait bitwise parity over fuzzed cache histories: every order,
/// warmup depth and horizon must produce the exact same f32 outputs.
#[test]
fn strategy_outputs_match_enum_paths_bitwise() {
    let pairs = [
        (DraftKind::Reuse, "reuse"),
        (DraftKind::AdamsBashforth, "adams-bashforth"),
        (DraftKind::Taylor, "taylor"),
    ];
    prop_check(200, 0xD2AF7, |rng| {
        let order = rng.below(4);
        let feat = 1 + rng.below(16);
        let interval = 1 + rng.below(8);
        let refreshes = 1 + rng.below(6);
        let mut cache = TapCache::new(order, feat, interval);
        for _ in 0..refreshes {
            cache.refresh(&rng.normal_f32s(feat));
        }
        let k = rng.range_f64(0.0, 2.0 * interval as f64) as f32;
        for (kind, name) in pairs {
            let strategy = Draft::named(name).map_err(|e| e.to_string())?;
            let mut via_enum = vec![0.0f32; feat];
            let mut via_trait = vec![0.0f32; feat];
            cache.predict_into(k, kind, &mut via_enum);
            cache.predict_with(&*strategy, k, &mut via_trait);
            if via_enum != via_trait {
                return Err(format!(
                    "{name}: order={order} refreshes={refreshes} k={k}: {via_enum:?} != {via_trait:?}"
                ));
            }
        }
        Ok(())
    });
}

fn run_engine(model: &NativeBackend, policy: &Policy, n: usize) -> Vec<Completion> {
    let mut engine = Engine::from_ref(model, EngineConfig::default());
    for r in batch_requests(n, 4, policy, 7, false) {
        engine.submit(r);
    }
    let mut done = engine.run_to_completion().unwrap();
    done.sort_by_key(|c| c.id);
    done
}

fn assert_runs_identical(a: &[Completion], b: &[Completion]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.latent, y.latent, "latents diverged for request {}", x.id);
        assert_eq!(
            x.stats.verify_trace,
            y.stats.verify_trace,
            "verify traces diverged for request {}",
            x.id
        );
        assert_eq!(x.stats.full_steps, y.stats.full_steps);
        assert_eq!(x.stats.rejects, y.stats.rejects);
        assert_eq!(x.stats.flops.total(), y.stats.flops.total());
    }
}

/// End-to-end parity: an engine run whose SpeCa policy resolves each
/// legacy draft through the registry is bitwise identical (latents,
/// verify traces, step/FLOPs accounting) to one whose config is built
/// with the same strategy directly — and the registry default (`taylor`)
/// matches a policy that names no draft at all.
#[test]
fn engine_runs_are_identical_across_resolution_paths() {
    let model = NativeBackend::seeded(ModelConfig::native_test(), 0xBEEF);
    let depth = model.entry().config.depth;
    for name in ["reuse", "adams-bashforth", "taylor"] {
        let by_name =
            parse_policy(&format!("speca:N=4,O=2,tau0=0.2,beta=0.3,draft={name}"), depth)
                .unwrap();
        let mut cfg = SpeCaConfig::default_for_depth(depth);
        cfg.interval = 4;
        cfg.order = 2;
        cfg.tau0 = 0.2;
        cfg.beta = 0.3;
        cfg.draft = DraftRegistry::global().resolve(name).unwrap();
        let direct = Policy::SpeCa(cfg);
        assert_runs_identical(&run_engine(&model, &by_name, 3), &run_engine(&model, &direct, 3));
    }
    let implicit = parse_policy("speca:N=4,O=2,tau0=0.2,beta=0.3", depth).unwrap();
    let explicit = parse_policy("speca:N=4,O=2,tau0=0.2,beta=0.3,draft=taylor", depth).unwrap();
    assert_runs_identical(&run_engine(&model, &implicit, 3), &run_engine(&model, &explicit, 3));
}

/// The two new strategies run end-to-end through the engine, label their
/// completions, and actually change what is predicted (they are not
/// aliases of the existing drafts).
#[test]
fn new_strategies_serve_and_differ() {
    let model = NativeBackend::seeded(ModelConfig::native_test(), 0xBEEF);
    let depth = model.entry().config.depth;
    let point = "speca:N=4,O=2,tau0=0.2,beta=0.3";
    let mut by_draft = Vec::new();
    for name in ["taylor", "richardson", "learned-linear"] {
        let policy = parse_policy(&format!("{point},draft={name}"), depth).unwrap();
        let done = run_engine(&model, &policy, 2);
        assert_eq!(done.len(), 2);
        for c in &done {
            assert_eq!(c.draft_name, name, "completion must carry the strategy name");
            assert!(!c.stats.verify_trace.is_empty(), "{name}: nothing was verified");
        }
        by_draft.push((name, done));
    }
    // distinct drafts ⇒ distinct verify-error sequences (same seeds, same
    // schedule — only the predictor changed)
    let trace_of = |i: usize| {
        by_draft[i].1[0].stats.verify_trace.iter().map(|(_, e, _)| *e).collect::<Vec<f64>>()
    };
    assert_ne!(trace_of(0), trace_of(1), "richardson must not equal taylor");
    assert_ne!(trace_of(0), trace_of(2), "learned-linear must not equal taylor");
    assert_ne!(trace_of(1), trace_of(2), "richardson must not equal learned-linear");
}

/// Fuzzed determinism of the new strategies: identical histories produce
/// identical outputs (no hidden per-call state), and `reset()` does not
/// perturb subsequent predictions.
#[test]
fn new_strategies_are_deterministic_and_reset_safe() {
    prop_check(100, 0x5EED5, |rng| {
        let feat = 1 + rng.below(12);
        let mut cache = TapCache::new(3, feat, 5);
        for _ in 0..(1 + rng.below(5)) {
            cache.refresh(&rng.normal_f32s(feat));
        }
        let k = rng.range_f64(0.5, 8.0) as f32;
        for name in ["richardson", "learned-linear"] {
            let d = Draft::named(name).map_err(|e| e.to_string())?;
            let mut a = vec![0.0f32; feat];
            let mut b = vec![0.0f32; feat];
            cache.predict_with(&*d, k, &mut a);
            d.reset();
            cache.predict_with(&*d, k, &mut b);
            if a != b {
                return Err(format!("{name}: reset() changed a stateless prediction"));
            }
            if !a.iter().all(|v| v.is_finite()) {
                return Err(format!("{name}: non-finite prediction"));
            }
        }
        Ok(())
    });
}

/// Warmup degradation contract: with a single refresh every registered
/// strategy predicts exactly the cached feature (reuse).
#[test]
fn all_strategies_degrade_to_reuse_during_warmup() {
    let mut rng = Rng::new(3);
    let feat = 6;
    let first = rng.normal_f32s(feat);
    let mut cache = TapCache::new(3, feat, 5);
    cache.refresh(&first);
    for name in DraftRegistry::global().names() {
        let d = DraftRegistry::global().resolve(name).unwrap();
        let mut out = vec![0.0f32; feat];
        cache.predict_with(&*d, 3.0, &mut out);
        assert_eq!(out, first, "{name} must reuse with one refresh observed");
    }
}
