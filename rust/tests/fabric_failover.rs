//! Integration: the multi-process serving fabric end-to-end over
//! loopback TCP — router + two workers in-process (threads stand in for
//! processes; the boundary is real TCP either way), driven through the
//! client-facing wire protocol v2.
//!
//! The headline test kills one worker mid-request and asserts the
//! no-lost-accepted-jobs contract: every job the router acked completes
//! with a final latent bitwise-identical to a single-process reference
//! run of the same (cond, seed, policy) on the same deterministic
//! error-injection backend (`speca::workload::scripted`), whether the
//! job rode out the failure on the surviving worker, resumed there from
//! a spilled checkpoint, or was re-run from scratch under its pinned
//! seed. The failover counters and the Prometheus-style `op:"metrics"`
//! plane are asserted in the same run; protocol-hardening paths
//! (structured errors for wrong-port/wrong-version peers) get their own
//! test.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use speca::config::ModelConfig;
use speca::coordinator::state::RequestSpec;
use speca::coordinator::{Engine, EngineConfig, JobMeta};
use speca::fabric::{spawn_router, spawn_worker, RouterConfig, WorkerConfig};
use speca::runtime::ModelBackend;
use speca::server::client;
use speca::util::json::Json;
use speca::workload::parse_policy;
use speca::workload::scripted::ScriptedBackend;

/// Alternating tiny/large drift: a mixed accept/reject verify trace, so
/// checkpoints carry non-trivial cache + controller state.
const DRIFT: &[f32] = &[0.001, 0.35];
const POLICY: &str = "speca:N=4,O=1,tau0=0.3,beta=0.05";

fn send(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line).unwrap_or_else(|e| panic!("bad response '{line}': {e}"))
}

fn connect(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connecting");
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

/// Single-process reference: the final latent of (cond, seed) under
/// `POLICY` on a drift-identical (but undelayed) scripted backend.
fn reference_latent(model: &Arc<ScriptedBackend>, cond: i32, seed: u64) -> Vec<f32> {
    let depth = model.entry().config.depth;
    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(RequestSpec {
        id: seed,
        cond,
        seed,
        policy: parse_policy(POLICY, depth).unwrap(),
        record_traj: false,
        meta: JobMeta::default(),
    });
    let mut done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    done.pop().unwrap().latent
}

/// The value of an unlabelled sample line in Prometheus exposition text.
fn metric_value(text: &str, name: &str) -> Option<f64> {
    text.lines().find_map(|l| l.strip_prefix(&format!("{name} "))?.trim().parse().ok())
}

#[test]
fn dead_worker_failover_loses_no_accepted_jobs() {
    let cfg = ModelConfig::native_test();
    // per-step delay keeps every job in flight long enough to be killed
    // mid-request and to cross at least one heartbeat (spill) boundary
    let slow =
        Arc::new(ScriptedBackend::new(cfg.clone(), DRIFT).with_delay(Duration::from_millis(5)));
    let fast = Arc::new(ScriptedBackend::new(cfg, DRIFT));

    // a tight heartbeat spills checkpoints often; the generous miss
    // limit means death is detected by the dropped connection (instant,
    // deterministic), not by timing-sensitive missed-pong accounting
    let router = spawn_router(&RouterConfig {
        addr: "127.0.0.1:0".into(),
        workers_addr: "127.0.0.1:0".into(),
        heartbeat_ms: 25,
        miss_limit: 40,
        ..RouterConfig::default()
    })
    .unwrap();
    let addr = router.addr().to_string();
    let join = router.workers_addr().to_string();
    let mk_worker = || {
        spawn_worker(
            slow.clone(),
            EngineConfig::default(),
            &WorkerConfig { join: join.clone(), ..WorkerConfig::default() },
        )
        .unwrap()
    };
    let w0 = mk_worker();
    let w1 = mk_worker();
    for _ in 0..400 {
        if router.workers_live() == 2 {
            break;
        }
        thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(router.workers_live(), 2, "both workers joined");

    let (mut stream, mut reader) = connect(&addr);
    let role = client::hello_exchange(&mut stream, &mut reader).unwrap();
    assert_eq!(role, "router");

    // submit 8 jobs; the booking-weighted router spreads them over both
    // workers, so the kill below always orphans in-flight work
    let n = 8usize;
    let mut jobs = Vec::new();
    for i in 0..n {
        let (cond, seed) = ((i % 4) as i32, 5000 + i as u64);
        let req = format!(
            "{{\"op\":\"submit\",\"cond\":{cond},\"seed\":{seed},\
             \"policy\":\"{POLICY}\",\"return_latent\":true}}"
        );
        let ack = send(&mut stream, &mut reader, &req);
        assert_eq!(ack.req("ok").as_bool(), Some(true), "submit {i} acked");
        assert_eq!(ack.req("state").as_str(), Some("queued"), "submit {i} accepted");
        jobs.push((ack.req("job").as_u64().unwrap(), cond, seed));
    }

    // let the jobs get airborne (and at least one heartbeat spill
    // through), then kill worker 0 mid-flight — socket torn down, pool
    // abandoned, no drain
    thread::sleep(Duration::from_millis(40));
    w0.kill();

    // every accepted job must still complete, bitwise-identical to the
    // single-process reference
    for (job, cond, seed) in &jobs {
        let reply = send(&mut stream, &mut reader, &format!("{{\"op\":\"wait\",\"job\":{job}}}"));
        assert_eq!(
            reply.req("state").as_str(),
            Some("completed"),
            "job {job} survived the failover: {}",
            reply.dump()
        );
        let got = reply.req("latent").f32s();
        let want = reference_latent(&fast, *cond, *seed);
        assert!(!want.is_empty(), "reference produced a latent");
        assert_eq!(got, want, "job {job} (cond {cond}, seed {seed}) latent drifted");
    }

    assert_eq!(router.failovers(), 1, "exactly the killed worker failed over");
    assert!(router.requeued_jobs() >= 1, "the dead worker's in-flight jobs were re-queued");
    assert_eq!(router.workers_live(), 1, "one survivor");

    // the metrics plane agrees, in parseable exposition text
    let text = client::metrics(&addr).unwrap();
    assert!(text.contains("# TYPE speca_failovers_total counter"), "{text}");
    assert_eq!(metric_value(&text, "speca_failovers_total"), Some(1.0), "{text}");
    assert_eq!(metric_value(&text, "speca_workers_live"), Some(1.0), "{text}");
    assert!(
        metric_value(&text, "speca_requeued_jobs_total").unwrap_or(0.0) >= 1.0,
        "{text}"
    );

    // the surviving worker's own serving port exports manager metrics
    let wtext = client::metrics(&w1.client_addr().to_string()).unwrap();
    assert!(wtext.contains("# TYPE speca_shard_up gauge"), "{wtext}");
    assert!(
        metric_value(&wtext, "speca_jobs_completed_total").unwrap_or(0.0) >= 1.0,
        "worker 1 completed failed-over work: {wtext}"
    );

    // aggregated stats null the dead worker like a dead shard
    let stats = client::stats(&addr).unwrap();
    let workers = stats.req("workers").as_arr().unwrap().clone();
    assert_eq!(workers.len(), 2);
    assert_eq!(workers[0], Json::Null, "dead worker reports null");
    assert!(workers[1].get("shard_loads").is_some(), "live worker reports its stats body");

    drop((stream, reader));
    client::shutdown(&addr);
    router.join().unwrap();
    w1.join().unwrap();
}

#[test]
fn fabric_ports_reject_strangers_with_structured_errors() {
    let router = spawn_router(&RouterConfig {
        addr: "127.0.0.1:0".into(),
        workers_addr: "127.0.0.1:0".into(),
        ..RouterConfig::default()
    })
    .unwrap();
    let addr = router.addr().to_string();
    let fabric_addr = router.workers_addr().to_string();

    // a v2 client op on the fabric port: structured error, then close —
    // never a hang or a silent drop
    let (mut s, mut r) = connect(&fabric_addr);
    let resp = send(&mut s, &mut r, "{\"op\":\"submit\",\"cond\":1}");
    assert_eq!(resp.req("ok").as_bool(), Some(false));
    let err = resp.req("error").as_str().unwrap_or_default().to_string();
    assert!(err.contains("SPFB"), "error names the expected handshake: {err}");
    let mut rest = String::new();
    assert_eq!(r.read_line(&mut rest).unwrap(), 0, "connection closed after the error");

    // version skew on the client port is named explicitly
    let (mut s, mut r) = connect(&addr);
    let resp = send(&mut s, &mut r, "{\"op\":\"hello\",\"proto\":\"speca\",\"version\":9}");
    assert_eq!(resp.req("ok").as_bool(), Some(false));
    let err = resp.req("error").as_str().unwrap_or_default().to_string();
    assert!(err.contains("version 9"), "{err}");

    // wrong protocol name, same deal
    let resp = send(&mut s, &mut r, "{\"op\":\"hello\",\"proto\":\"http\"}");
    assert_eq!(resp.req("ok").as_bool(), Some(false));

    // unknown ops are structured errors, not silent generates
    let resp = send(&mut s, &mut r, "{\"op\":\"frobnicate\"}");
    assert_eq!(resp.req("ok").as_bool(), Some(false));
    let err = resp.req("error").as_str().unwrap_or_default().to_string();
    assert!(err.contains("unknown op"), "{err}");

    // a well-formed hello succeeds and names the role
    let role = client::hello_exchange(&mut s, &mut r).unwrap();
    assert_eq!(role, "router");

    // submitting with no workers joined is an explicit abort, not a hang
    let resp = send(&mut s, &mut r, "{\"op\":\"submit\",\"cond\":0}");
    assert_eq!(resp.req("ok").as_bool(), Some(false));
    assert_eq!(resp.req("state").as_str(), Some("aborted"));

    drop((s, r));
    client::shutdown(&addr);
    router.join().unwrap();
}
