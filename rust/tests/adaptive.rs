//! Integration: sample-adaptive computation allocation (DESIGN.md §14)
//! driven end-to-end on the deterministic error-injection backend
//! (`speca::workload::scripted`). The drift scripts decide every verify
//! outcome in advance, so the controller's observable behaviour is
//! pinned step by step: rejection streaks tighten the draft rung and
//! halve the threshold scale down to the dense-fallback latch, dense
//! probation retries speculation, sustained acceptance loosens rung and
//! scale back, and a zero budget pins every step dense. On top of that,
//! controller state survives park/resume, the SPCK byte codec, priority
//! preemption and cross-shard work stealing bitwise.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use speca::config::ModelConfig;
use speca::coordinator::adaptive::CtlCheckpoint;
use speca::coordinator::state::{Completion, RequestCheckpoint, RequestSpec};
use speca::coordinator::{
    Admission, Engine, EngineConfig, EngineShardPool, JobMeta, PoolConfig, Priority, RouterPolicy,
};
use speca::runtime::ModelBackend;
use speca::workload::parse_policy;
use speca::workload::scripted::ScriptedBackend;

/// Per-step rel error far below any threshold: every verify accepts.
const EASY: &[f32] = &[0.0005];
/// Per-step rel error far above any threshold: every verify rejects.
const HARD: &[f32] = &[0.75];
/// Alternating tiny/large drift: a mixed accept/reject trace.
const MIXED: &[f32] = &[0.001, 0.35];

/// An adaptive request whose budget never binds (`tau0` stays the base),
/// so the trace is driven purely by streak dynamics.
const ROOMY: &str = "speca:N=12,O=1,tau0=0.3,beta=1,metric=l1,adaptive=10";

fn scripted(drift: &[f32]) -> Arc<ScriptedBackend> {
    Arc::new(ScriptedBackend::new(ModelConfig::native_test(), drift))
}

fn spec(id: u64, depth: usize, desc: &str) -> RequestSpec {
    RequestSpec {
        id,
        cond: (id % 4) as i32,
        seed: 100 + id,
        policy: parse_policy(desc, depth).unwrap(),
        record_traj: false,
        meta: JobMeta::default(),
    }
}

/// The request run start-to-finish on one engine with no interruption —
/// the reference every park/resume variant must match bitwise.
fn run_uninterrupted(model: &Arc<ScriptedBackend>, s: RequestSpec) -> Completion {
    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(s);
    let mut done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    done.pop().unwrap()
}

/// Everything observable about a completion except wall-clock latency
/// must match exactly.
fn assert_bitwise(a: &Completion, b: &Completion, what: &str) {
    assert_eq!(a.id, b.id, "{what}: id");
    assert_eq!(a.policy_name, b.policy_name, "{what}: policy");
    assert_eq!(a.latent, b.latent, "{what}: final latent drifted");
    assert_eq!(a.stats.full_steps, b.stats.full_steps, "{what}: full steps");
    assert_eq!(a.stats.spec_steps, b.stats.spec_steps, "{what}: spec steps");
    assert_eq!(a.stats.rejects, b.stats.rejects, "{what}: rejects");
    assert_eq!(a.stats.verify_trace, b.stats.verify_trace, "{what}: verify trace");
    assert_eq!(a.stats.flops.total(), b.stats.flops.total(), "{what}: booked FLOPs");
}

/// Park the engine's single in-flight request and hand back its
/// checkpoint for inspection (the caller resumes it afterwards).
fn park_one(engine: &mut Engine<'_>, at: usize) -> Box<RequestCheckpoint> {
    let mut units = engine.park_all();
    assert_eq!(units.len(), 1, "boundary {at}: expected one in-flight request");
    let Some(Admission::Parked(ckpt)) = units.pop() else {
        panic!("boundary {at}: park_all returned a fresh spec");
    };
    assert_eq!(ckpt.step, at, "parked off-boundary");
    ckpt
}

/// One expected controller snapshot row: (rung, draft, tau_scale,
/// accept_streak, reject_streak, dense, probation, dense_steps).
type Row = (u32, &'static str, f64, u32, u32, bool, u32, u64);

fn assert_ctl(ctl: &CtlCheckpoint, row: &Row, at: usize) {
    let (rung, draft, scale, a, r, dense, prob, ds) = *row;
    assert_eq!(ctl.snap.rung, rung, "boundary {at}: rung");
    assert_eq!(ctl.draft, draft, "boundary {at}: draft");
    assert_eq!(ctl.snap.tau_scale, scale, "boundary {at}: tau scale");
    assert_eq!(ctl.snap.accept_streak, a, "boundary {at}: accept streak");
    assert_eq!(ctl.snap.reject_streak, r, "boundary {at}: reject streak");
    assert_eq!(ctl.snap.dense, dense, "boundary {at}: dense latch");
    assert_eq!(ctl.snap.probation, prob, "boundary {at}: probation");
    assert_eq!(ctl.snap.dense_steps, ds, "boundary {at}: dense steps");
}

/// ISSUE acceptance (a): under the same budget, the hard script ends
/// with more dense (full) steps than the easy one, and a zero budget
/// degrades to dense-only from the start.
#[test]
fn hard_scripts_spend_more_dense_steps_than_easy_under_the_same_budget() {
    let desc = "speca:N=12,O=1,tau0=0.3,beta=1,metric=l1,adaptive=0.1";
    let easy_model = scripted(EASY);
    let depth = easy_model.entry().config.depth;
    let easy = run_uninterrupted(&easy_model, spec(0, depth, desc));
    let hard = run_uninterrupted(&scripted(HARD), spec(0, depth, desc));

    // the easy script accepts every speculative step: only the step-0
    // refresh is dense; the hard script rejects itself down the ladder
    // into the dense latch and ends all-dense
    assert_eq!(easy.stats.full_steps, 1, "easy: only the warmup refresh is dense");
    assert_eq!(easy.stats.spec_steps, 11, "easy: every other step speculates");
    assert_eq!(easy.stats.rejects, 0, "easy: nothing rejects");
    assert_eq!(hard.stats.full_steps, 12, "hard: every step ends up dense");
    assert_eq!(hard.stats.spec_steps, 0, "hard: no speculation survives");
    assert!(hard.stats.rejects > 0, "hard: the ladder walk-down is reject-driven");
    assert!(
        hard.stats.full_steps > easy.stats.full_steps,
        "the same budget must buy more dense compute on the harder sample"
    );

    // a zero budget means no error allowance at all: the controller
    // forces dense from the first speculative opportunity, without a
    // single verify (nothing is ever risked)
    let none = run_uninterrupted(
        &easy_model,
        spec(1, depth, "speca:N=12,O=1,tau0=0.3,beta=1,metric=l1,adaptive=0"),
    );
    assert_eq!(none.stats.full_steps, 12, "zero budget: all dense");
    assert_eq!(none.stats.spec_steps, 0);
    assert_eq!(none.stats.rejects, 0);
    assert!(none.stats.verify_trace.is_empty(), "zero budget: nothing is verified");
}

/// Step-by-step tighten/fallback/probation proof on a constant-hard
/// script: every verify rejects, so the controller must walk the ladder
/// taylor → adams-bashforth → reuse (halving the threshold scale at
/// each tighten), latch dense at the bottom, sit out the probation
/// window, retry speculation, and latch again. The controller state is
/// observed by parking at every boundary — which also proves the
/// inspection itself is bitwise-invisible.
#[test]
fn rejection_streaks_tighten_to_the_dense_latch_and_probation_retries() {
    let model = scripted(HARD);
    let depth = model.entry().config.depth;
    let reference = run_uninterrupted(&model, spec(0, depth, ROOMY));

    // boundary k = engine state after serve steps 0..k. Steps 1..=6
    // reject (streaks of 2 tighten at boundaries 3/5/7; the third
    // tighten has no deeper rung and latches dense), 7..=9 are forced
    // dense (probation expires at boundary 10), 10..=11 reject again.
    let expect: [Row; 11] = [
        (0, "taylor", 1.0, 0, 0, false, 0, 0),
        (0, "taylor", 1.0, 0, 1, false, 0, 0),
        (1, "adams-bashforth", 0.5, 0, 0, false, 0, 0),
        (1, "adams-bashforth", 0.5, 0, 1, false, 0, 0),
        (2, "reuse", 0.25, 0, 0, false, 0, 0),
        (2, "reuse", 0.25, 0, 1, false, 0, 0),
        (2, "reuse", 0.25, 0, 0, true, 0, 0),
        (2, "reuse", 0.25, 0, 0, true, 1, 1),
        (2, "reuse", 0.25, 0, 0, true, 2, 2),
        (2, "reuse", 0.25, 0, 0, false, 0, 3),
        (2, "reuse", 0.25, 0, 1, false, 0, 3),
    ];

    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(spec(0, depth, ROOMY));
    for (i, row) in expect.iter().enumerate() {
        let at = i + 1;
        assert!(engine.tick().unwrap(), "engine went idle before boundary {at}");
        let ckpt = park_one(&mut engine, at);
        let ctl = ckpt.ctl.as_ref().expect("adaptive requests checkpoint their controller");
        assert_eq!(ctl.total, 10.0, "boundary {at}: configured budget");
        // rejects never spend budget: it stays whole through the walk
        assert_eq!(ctl.snap.budget_left, 10.0, "boundary {at}: budget spent on a reject");
        assert_ctl(ctl, row, at);
        engine.submit_checkpoint(ckpt);
    }
    let mut done = engine.run_to_completion().unwrap();
    assert_eq!(engine.parked, 11);
    assert_eq!(engine.resumed, 11);

    let hard = done.pop().unwrap();
    assert_eq!(hard.stats.full_steps, 12);
    assert_eq!(hard.stats.spec_steps, 0);
    assert_eq!(hard.stats.rejects, 8);
    // the recorded thresholds show the halving applied at verify time:
    // rejected steps 1,2 at scale 1, 3,4 at 1/2, 5,6 and 10,11 at 1/4
    let scales = [1.0, 1.0, 0.5, 0.5, 0.25, 0.25, 0.25, 0.25];
    let steps = [1, 2, 3, 4, 5, 6, 10, 11];
    assert_eq!(hard.stats.verify_trace.len(), 8);
    for (i, (step, e, tau)) in hard.stats.verify_trace.iter().enumerate() {
        assert_eq!(*step, steps[i], "verify {i}: step");
        assert_eq!(*tau, 0.3 * scales[i], "verify {i}: applied threshold");
        assert!(e > tau, "verify {i}: scripted drift must reject");
    }
    assert_bitwise(&reference, &hard, "11 park/inspect cycles");
}

/// Step-by-step loosen proof: two early rejects tighten to the
/// adams-bashforth rung at half scale, then a run of tiny-drift steps
/// accepts; the third consecutive accept loosens the scale back to 1
/// and climbs back to the configured taylor rung, and further accept
/// streaks saturate there. Budget drains only on accepts.
#[test]
fn sustained_acceptance_loosens_the_rung_and_threshold_back() {
    let mut drift = vec![0.001f32; 12];
    drift[1] = 0.35;
    drift[2] = 0.35;
    let model = scripted(&drift);
    let depth = model.entry().config.depth;
    let reference = run_uninterrupted(&model, spec(0, depth, ROOMY));

    // steps 1,2 reject (tighten at boundary 3), steps 3.. accept; the
    // loosen fires on every third consecutive accept (boundaries 6, 9)
    // and then only resets the streak (scale and rung are saturated)
    let expect: [Row; 11] = [
        (0, "taylor", 1.0, 0, 0, false, 0, 0),
        (0, "taylor", 1.0, 0, 1, false, 0, 0),
        (1, "adams-bashforth", 0.5, 0, 0, false, 0, 0),
        (1, "adams-bashforth", 0.5, 1, 0, false, 0, 0),
        (1, "adams-bashforth", 0.5, 2, 0, false, 0, 0),
        (0, "taylor", 1.0, 0, 0, false, 0, 0),
        (0, "taylor", 1.0, 1, 0, false, 0, 0),
        (0, "taylor", 1.0, 2, 0, false, 0, 0),
        (0, "taylor", 1.0, 0, 0, false, 0, 0),
        (0, "taylor", 1.0, 1, 0, false, 0, 0),
        (0, "taylor", 1.0, 2, 0, false, 0, 0),
    ];

    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(spec(0, depth, ROOMY));
    let mut last_budget = 10.0f64;
    for (i, row) in expect.iter().enumerate() {
        let at = i + 1;
        assert!(engine.tick().unwrap(), "engine went idle before boundary {at}");
        let ckpt = park_one(&mut engine, at);
        let ctl = ckpt.ctl.as_ref().expect("adaptive requests checkpoint their controller");
        assert_ctl(ctl, row, at);
        if at <= 3 {
            assert_eq!(ctl.snap.budget_left, 10.0, "boundary {at}: rejects spend nothing");
        } else {
            assert!(
                ctl.snap.budget_left < last_budget,
                "boundary {at}: each accept must drain the budget"
            );
        }
        last_budget = ctl.snap.budget_left;
        engine.submit_checkpoint(ckpt);
    }
    let mut done = engine.run_to_completion().unwrap();
    let got = done.pop().unwrap();
    assert_eq!(got.stats.rejects, 2);
    assert_eq!(got.stats.full_steps, 3, "steps 0,1,2 are the only dense ones");
    assert_eq!(got.stats.spec_steps, 9);
    assert_bitwise(&reference, &got, "11 park/inspect cycles");
}

/// ISSUE acceptance (b): a parked-then-resumed adaptive job — including
/// a trip through the SPCK v2 byte codec at every boundary — finishes
/// bitwise-identical to the uninterrupted run.
#[test]
fn adaptive_park_resume_and_byte_codec_are_bitwise_at_every_boundary() {
    let desc = "speca:N=12,O=1,tau0=0.3,beta=1,metric=l1,adaptive=0.5";
    let model = scripted(MIXED);
    let depth = model.entry().config.depth;
    let total = model.entry().config.serve_steps;
    let reference = run_uninterrupted(&model, spec(0, depth, desc));
    for boundary in 1..total {
        let mut engine = Engine::new(model.clone(), EngineConfig::default());
        engine.submit(spec(0, depth, desc));
        for _ in 0..boundary {
            assert!(engine.tick().unwrap(), "engine idle before boundary {boundary}");
        }
        let ckpt = park_one(&mut engine, boundary);
        let ctl = ckpt.ctl.as_ref().expect("the controller must be checkpointed");
        if boundary >= 3 {
            // the step-2 accept has spent budget by then: the codec trip
            // below round-trips *live* controller state, not defaults
            assert!(ctl.snap.budget_left < ctl.total, "boundary {boundary}: stale budget");
        }
        let bytes = ckpt.to_bytes();
        let (policy, meta) = (ckpt.spec.policy.clone(), ckpt.spec.meta.clone());
        let decoded = RequestCheckpoint::from_bytes(&bytes, policy, meta)
            .expect("a parked image must decode");
        assert_eq!(decoded.to_bytes(), bytes, "boundary {boundary}: codec not canonical");
        let mut peer = Engine::new(model.clone(), EngineConfig::default());
        peer.submit_checkpoint(Box::new(decoded));
        let mut done = peer.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(peer.resumed, 1);
        let what = format!("codec resume at boundary {boundary}");
        assert_bitwise(&reference, &done.pop().unwrap(), &what);
    }
}

/// ISSUE acceptance (c): SPCK v1 images (no controller appendix, no
/// lookahead appendix) from a static request on the scripted backend
/// still decode, upgrade to the current version, and resume bitwise.
#[test]
fn spck_v1_images_from_static_requests_decode_and_resume_bitwise() {
    let desc = "speca:N=5,O=1,tau0=0.05,beta=1,metric=l1";
    let model = scripted(MIXED);
    let depth = model.entry().config.depth;
    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(spec(0, depth, desc));
    for _ in 0..4 {
        assert!(engine.tick().unwrap());
    }
    let ckpt = park_one(&mut engine, 4);
    let v3 = ckpt.to_bytes();
    // a static cap-1 image ends in [ctl flag 0][hist len 2][2 hist
    // words][run flag 0]; strip the whole 32-byte tail and patch the
    // version field — byte-for-byte the layout a v1 writer produced
    let n = v3.len();
    assert_eq!(&v3[n - 4..], &[0u8; 4], "static k=1 requests park outside a run");
    assert_eq!(&v3[n - 28..n - 20], &2u64.to_le_bytes(), "cap-1 histogram length");
    assert_eq!(&v3[n - 32..n - 28], &[0u8; 4], "static requests carry no controller");
    let mut v1 = v3[..n - 32].to_vec();
    v1[4..8].copy_from_slice(&1u32.to_le_bytes());
    let decoded = RequestCheckpoint::from_bytes(&v1, ckpt.spec.policy.clone(), ckpt.spec.meta)
        .expect("v1 images must keep decoding");
    assert!(decoded.ctl.is_none(), "v1 images carry no controller state");
    assert!(decoded.look.is_empty(), "v1 images carry no in-flight run");
    // the upgrade re-adds the two zero flags verbatim; the histogram is
    // the one record a v1 writer never kept, so it comes back zeroed
    let mut expect = v3.clone();
    expect[n - 20..n - 4].fill(0);
    assert_eq!(decoded.to_bytes(), expect, "the v1→v3 upgrade zeroes only the histogram");
    let reference = run_uninterrupted(&model, spec(0, depth, desc));
    let mut peer = Engine::new(model.clone(), EngineConfig::default());
    peer.submit_checkpoint(Box::new(decoded));
    let done = peer.run_to_completion().unwrap();
    assert_bitwise(&reference, &done[0], "v1 image resume");
}

/// Priority preemption ported onto the scripted backend: the parked
/// victim carries live controller state through the round trip and
/// still finishes bitwise-identical.
#[test]
fn preemption_round_trips_the_adaptive_victim_bitwise() {
    let desc = "speca:N=12,O=1,tau0=0.3,beta=1,metric=l1,adaptive=0.5";
    let model = scripted(MIXED);
    let depth = model.entry().config.depth;
    let mut low = spec(0, depth, desc);
    low.meta.priority = Priority::Low;
    low.meta.preemptible = true;
    let reference = run_uninterrupted(&model, low.clone());

    let cfg = EngineConfig { max_inflight: 1, ..EngineConfig::default() };
    let mut engine = Engine::new(model.clone(), cfg);
    engine.submit(low);
    for _ in 0..3 {
        assert!(engine.tick().unwrap());
    }
    let mut high = spec(1, depth, "full");
    high.meta.priority = Priority::High;
    engine.submit(high);
    let mut done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    assert_eq!(engine.parked, 1, "the adaptive victim must be parked exactly once");
    assert_eq!(engine.resumed, 1, "... and resumed after the high job finishes");
    assert_eq!(done[0].id, 1, "high-priority job must finish first");
    done.sort_by_key(|c| c.id);
    assert_bitwise(&reference, &done[0], "preempted adaptive victim");
}

/// Work stealing ported onto the scripted backend: an idle shard steals
/// mid-flight adaptive work from a loaded peer, and every stolen job's
/// outcome is bitwise-identical to a single-engine run — the controller
/// state travels with the checkpoint across shard threads.
#[test]
fn idle_shard_steals_adaptive_work_and_outcomes_stay_bitwise() {
    let desc = "speca:N=12,O=1,tau0=0.3,beta=1,metric=l1,adaptive=0.5";
    let cfg = ModelConfig::native_test();
    let slow = Arc::new(ScriptedBackend::new(cfg, MIXED).with_delay(Duration::from_millis(15)));
    let fast = scripted(MIXED); // same math, no sleeps: the reference
    let depth = slow.entry().config.depth;
    let pool = EngineShardPool::new(
        slow,
        PoolConfig {
            shards: 2,
            router: RouterPolicy::LeastLoaded,
            engine: EngineConfig::default(),
            steal: true,
        },
    );

    // a quick job with a heavy cost hint parks shard 0's work gauge
    // high, steering the slow preemptible adaptive backlog to shard 1 —
    // a deliberately skewed placement the thief must then repair
    let mut quick = spec(0, depth, "steps:keep=2");
    quick.meta.cost_hint = 60.0;
    assert_eq!(pool.submit(quick).unwrap(), 0);
    for i in 1..=4 {
        let mut s = spec(i, depth, desc);
        s.meta.cost_hint = 5.0;
        s.meta.preemptible = true;
        assert_eq!(pool.submit(s).unwrap(), 1, "hinted routing must skew to shard 1");
    }

    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let s = pool.stats();
        if s.stolen >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "idle shard never stole: {s:?}");
        thread::sleep(Duration::from_millis(5));
    }

    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.completions.len(), 5, "stolen work must still complete");
    assert!(out.stats.stolen >= 1, "steal counter lost: {:?}", out.stats);
    assert!(out.stats.parked >= 1, "the victim parks a mid-flight unit: {:?}", out.stats);
    assert!(out.stats.resumed >= 1, "the thief resumes it: {:?}", out.stats);
    let mut done = out.completions;
    done.sort_by_key(|c| c.id);
    for (i, c) in done.iter().enumerate() {
        assert_eq!(c.id, i as u64);
        let d = if i == 0 { "steps:keep=2" } else { desc };
        let reference = run_uninterrupted(&fast, spec(i as u64, depth, d));
        assert_bitwise(&reference, c, "stolen/migrated shard work");
    }
}
