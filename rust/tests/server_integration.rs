//! Integration: TCP JSON-lines server end-to-end over localhost.
//! The engine (not `Send`) runs on the test thread; a client thread
//! drives generate/stats/shutdown.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use speca::config::Manifest;
use speca::coordinator::{Engine, EngineConfig};
use speca::runtime::{ModelRuntime, Runtime};
use speca::server::{serve, ServerConfig};
use speca::util::json::Json;

#[test]
fn server_round_trip() {
    let dir = speca::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("dit-sim").unwrap();
    let rt = Runtime::cpu().unwrap();
    let model = ModelRuntime::load(&rt, entry).unwrap();
    let mut engine = Engine::new(&model, EngineConfig::default());
    let addr = "127.0.0.1:17433";
    let cfg = ServerConfig { addr: addr.to_string(), max_queue: 64 };

    let client = thread::spawn(move || {
        // wait for the listener
        let mut stream = None;
        for _ in 0..100 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(_) => thread::sleep(Duration::from_millis(50)),
            }
        }
        let mut stream = stream.expect("server came up");
        let mut reader = BufReader::new(stream.try_clone().unwrap());

        // bad request → structured error
        stream.write_all(b"{\"op\":\"generate\",\"policy\":\"bogus\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.req("ok").as_bool(), Some(false));

        // two generations with latents returned
        let mut latents = Vec::new();
        for seed in [1u64, 2u64] {
            let req = format!(
                "{{\"op\":\"generate\",\"cond\":2,\"seed\":{seed},\
                 \"policy\":\"speca\",\"N\":5,\"tau0\":0.3,\"return_latent\":true}}\n"
            );
            stream.write_all(req.as_bytes()).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            assert_eq!(resp.req("ok").as_bool(), Some(true), "{line}");
            let stats = resp.req("stats");
            assert!(stats.req("latency_ms").as_f64().unwrap() > 0.0);
            assert!(stats.req("speedup").as_f64().unwrap() >= 1.0);
            let latent = resp.req("latent").f32s();
            assert!(!latent.is_empty());
            assert!(latent.iter().all(|v| v.is_finite()));
            latents.push(latent);
        }
        // distinct seeds → distinct outputs
        assert_ne!(latents[0], latents[1]);

        // stats op
        stream.write_all(b"{\"op\":\"stats\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.req("completed").as_u64(), Some(2));

        stream.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        let mut line = String::new();
        let _ = reader.read_line(&mut line);
    });

    let completed = serve(&mut engine, &cfg).unwrap();
    client.join().unwrap();
    assert_eq!(completed, 2);
}
