//! Integration: TCP JSON-lines server end-to-end over localhost, running
//! the engine on the zero-artifact native backend (no feature flags, no
//! `make artifacts`). Covers both serving modes: the legacy
//! single-threaded loop (engine on the test thread, client thread drives
//! generate/stats/shutdown and protocol error paths) and the sharded
//! pool front-end (concurrent clients, shard routing, graceful shutdown
//! with a request in flight).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use speca::config::ModelConfig;
use speca::coordinator::{Engine, EngineConfig};
use speca::runtime::NativeBackend;
use speca::server::{serve, serve_sharded, ServerConfig};
use speca::util::json::Json;

fn send(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line).unwrap_or_else(|e| panic!("bad response '{line}': {e}"))
}

#[test]
fn server_round_trip() {
    let model = NativeBackend::seeded(ModelConfig::native_test(), 0x5EED);
    let mut engine = Engine::from_ref(&model, EngineConfig::default());
    let addr = "127.0.0.1:17435";
    let cfg = ServerConfig { addr: addr.to_string(), max_queue: 64, ..ServerConfig::default() };

    let client = thread::spawn(move || {
        // wait for the listener
        let mut stream = None;
        for _ in 0..100 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(_) => thread::sleep(Duration::from_millis(50)),
            }
        }
        let mut stream = stream.expect("server came up");
        let mut reader = BufReader::new(stream.try_clone().unwrap());

        // bad policy → structured error
        let resp = send(&mut stream, &mut reader, "{\"op\":\"generate\",\"policy\":\"bogus\"}");
        assert_eq!(resp.req("ok").as_bool(), Some(false));

        // unknown op → rejected, not silently treated as generate
        let resp = send(&mut stream, &mut reader, "{\"op\":\"frobnicate\"}");
        assert_eq!(resp.req("ok").as_bool(), Some(false));
        let err = resp.req("error").as_str().unwrap_or_default().to_string();
        assert!(err.contains("unknown op"), "unexpected error '{err}'");

        // v2 job ops are a structured error on the single-threaded loop,
        // pointing at the sharded path (not a silent unknown-op)
        let resp = send(&mut stream, &mut reader, "{\"op\":\"poll\",\"job\":0}");
        assert_eq!(resp.req("ok").as_bool(), Some(false));
        let err = resp.req("error").as_str().unwrap_or_default().to_string();
        assert!(err.contains("sharded"), "unexpected error '{err}'");

        // two generations with latents returned
        let mut latents = Vec::new();
        for seed in [1u64, 2u64] {
            let req = format!(
                "{{\"op\":\"generate\",\"cond\":2,\"seed\":{seed},\
                 \"policy\":\"speca\",\"N\":5,\"tau0\":0.3,\"return_latent\":true}}"
            );
            let resp = send(&mut stream, &mut reader, &req);
            assert_eq!(resp.req("ok").as_bool(), Some(true));
            let stats = resp.req("stats");
            assert!(stats.req("latency_ms").as_f64().unwrap() > 0.0);
            assert!(stats.req("speedup").as_f64().unwrap() > 0.0);
            let latent = resp.req("latent").f32s();
            assert!(!latent.is_empty());
            assert!(latent.iter().all(|v| v.is_finite()));
            latents.push(latent);
        }
        // distinct seeds → distinct outputs
        assert_ne!(latents[0], latents[1]);

        // a request without "op" defaults to generate; FORA's fixed skip
        // pattern gives a deterministic FLOPs speedup well above 1
        let resp = send(&mut stream, &mut reader, "{\"policy\":\"fora\",\"N\":4,\"seed\":9}");
        assert_eq!(resp.req("ok").as_bool(), Some(true));
        assert!(resp.req("stats").req("speedup").as_f64().unwrap() > 2.0);

        // stats op
        let resp = send(&mut stream, &mut reader, "{\"op\":\"stats\"}");
        assert_eq!(resp.req("completed").as_u64(), Some(3));

        stream.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        let mut line = String::new();
        let _ = reader.read_line(&mut line);
    });

    let completed = serve(&mut engine, &cfg).unwrap();
    client.join().unwrap();
    assert_eq!(completed, 3);
}

/// Sharded front-end: two shards over one shared native backend,
/// concurrent clients, per-shard completion dispatch, stats aggregation,
/// and a graceful shutdown that still answers the request in flight.
#[test]
fn sharded_server_round_trip_and_graceful_shutdown() {
    let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 0x5EED));
    let addr = "127.0.0.1:17436";
    let server = {
        let model = model.clone();
        thread::spawn(move || {
            let cfg = ServerConfig {
                addr: addr.to_string(),
                max_queue: 64,
                shards: 2,
                ..ServerConfig::default()
            };
            serve_sharded(model, EngineConfig::default(), &cfg).unwrap()
        })
    };

    // two concurrent clients, two generates each, routed across shards
    let mut clients = Vec::new();
    for w in 0..2u64 {
        clients.push(thread::spawn(move || {
            let mut stream = connect_for_test(addr);
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut latents = Vec::new();
            for i in 0..2u64 {
                let req = format!(
                    "{{\"op\":\"generate\",\"cond\":1,\"seed\":{},\
                     \"policy\":\"speca\",\"N\":5,\"return_latent\":true}}",
                    10 + w * 2 + i
                );
                let resp = send(&mut stream, &mut reader, &req);
                assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
                let latent = resp.req("latent").f32s();
                assert!(latent.iter().all(|v| v.is_finite()));
                latents.push(latent);
            }
            latents
        }));
    }
    let mut all: Vec<Vec<f32>> = Vec::new();
    for c in clients {
        all.extend(c.join().unwrap());
    }
    // distinct seeds → distinct outputs, across shards too
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    all.dedup();
    assert_eq!(all.len(), 4, "four distinct seeds must give four distinct latents");

    let mut stream = connect_for_test(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let resp = send(&mut stream, &mut reader, "{\"op\":\"stats\"}");
    assert_eq!(resp.req("ok").as_bool(), Some(true));
    assert_eq!(resp.req("completed").as_u64(), Some(4));
    assert_eq!(resp.req("shards").as_u64(), Some(2));
    // unknown ops stay rejected in the sharded path
    let resp = send(&mut stream, &mut reader, "{\"op\":\"frobnicate\"}");
    assert_eq!(resp.req("ok").as_bool(), Some(false));

    // graceful shutdown with a request in flight: submit without reading
    // the reply, give the server a moment to route it, then shut down from
    // another connection — the drain must still answer the first request.
    let mut inflight = connect_for_test(addr);
    let mut inflight_reader = BufReader::new(inflight.try_clone().unwrap());
    inflight
        .write_all(b"{\"op\":\"generate\",\"seed\":99,\"policy\":\"speca\",\"N\":5}\n")
        .unwrap();
    thread::sleep(Duration::from_millis(100));
    let mut shutter = connect_for_test(addr);
    let mut shutter_reader = BufReader::new(shutter.try_clone().unwrap());
    let resp = send(&mut shutter, &mut shutter_reader, "{\"op\":\"shutdown\"}");
    assert_eq!(resp.req("ok").as_bool(), Some(true));
    let mut line = String::new();
    inflight_reader.read_line(&mut line).unwrap();
    let resp = Json::parse(&line).unwrap();
    assert_eq!(resp.req("ok").as_bool(), Some(true), "draining must answer in-flight work");

    let completed = server.join().unwrap();
    assert_eq!(completed, 5);
}

fn connect_for_test(addr: &str) -> TcpStream {
    for _ in 0..100 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        thread::sleep(Duration::from_millis(50));
    }
    panic!("server did not come up at {addr}");
}

/// Protocol v2 round trip: async submit acks immediately with a job id,
/// poll is an idempotent status snapshot, wait returns the completion
/// and consumes the record, cancel on a finished job is a no-op, the
/// stats op exposes per-shard live data, and the v1 generate shim keeps
/// its original reply shape on the same server.
#[test]
fn protocol_v2_submit_poll_wait_cancel_round_trip() {
    let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 0x5EED));
    let addr = "127.0.0.1:17437";
    let server = {
        let model = model.clone();
        thread::spawn(move || {
            let cfg = ServerConfig {
                addr: addr.to_string(),
                max_queue: 64,
                shards: 2,
                ..ServerConfig::default()
            };
            serve_sharded(model, EngineConfig::default(), &cfg).unwrap()
        })
    };
    let mut stream = connect_for_test(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // submit acks immediately with a job id (no completion payload)
    let resp = send(
        &mut stream,
        &mut reader,
        "{\"op\":\"submit\",\"seed\":5,\"policy\":\"speca\",\"N\":5,\
         \"return_latent\":true,\"priority\":\"high\",\"deadline_ms\":600000}",
    );
    assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
    assert_eq!(resp.req("state").as_str(), Some("queued"));
    assert!(resp.get("latent").is_none(), "submit must not block for the result");
    let job = resp.req("job").as_u64().expect("submit ack carries the job id");

    // wait blocks until terminal and returns the full completion —
    // including the latent recorded at submit time
    let resp = send(&mut stream, &mut reader, &format!("{{\"op\":\"wait\",\"job\":{job}}}"));
    assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
    assert_eq!(resp.req("state").as_str(), Some("completed"));
    assert!(resp.req("stats").req("speedup").as_f64().unwrap() > 0.0);
    let latent = resp.req("latent").f32s();
    assert!(!latent.is_empty() && latent.iter().all(|v| v.is_finite()));

    // the consuming wait removed the record: poll now errors
    let resp = send(&mut stream, &mut reader, &format!("{{\"op\":\"poll\",\"job\":{job}}}"));
    assert_eq!(resp.req("ok").as_bool(), Some(false));
    assert!(resp.req("error").as_str().unwrap_or_default().contains("unknown job"));

    // poll is idempotent until a wait consumes the record
    let resp =
        send(&mut stream, &mut reader, "{\"op\":\"submit\",\"seed\":6,\"policy\":\"fora\",\"N\":4}");
    assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
    let job2 = resp.req("job").as_u64().unwrap();
    let mut state = String::new();
    for _ in 0..600 {
        let resp = send(&mut stream, &mut reader, &format!("{{\"op\":\"poll\",\"job\":{job2}}}"));
        state = resp.req("state").as_str().unwrap_or_default().to_string();
        if state == "completed" {
            break;
        }
        thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(state, "completed", "job {job2} never completed");
    let resp = send(&mut stream, &mut reader, &format!("{{\"op\":\"poll\",\"job\":{job2}}}"));
    assert_eq!(resp.req("state").as_str(), Some("completed"), "poll must be idempotent");

    // cancel of a finished job: the terminal state wins
    let resp = send(&mut stream, &mut reader, &format!("{{\"op\":\"cancel\",\"job\":{job2}}}"));
    assert_eq!(resp.req("ok").as_bool(), Some(true));
    assert_eq!(resp.req("state").as_str(), Some("completed"));

    // structured errors: unknown priority, unknown job id
    let resp = send(&mut stream, &mut reader, "{\"op\":\"submit\",\"priority\":\"urgent\"}");
    assert_eq!(resp.req("ok").as_bool(), Some(false));
    assert!(resp.req("error").as_str().unwrap_or_default().contains("priority"));
    let resp = send(&mut stream, &mut reader, "{\"op\":\"wait\",\"job\":9999}");
    assert_eq!(resp.req("ok").as_bool(), Some(false));

    // stats: per-shard live loads, dead-shard count, job counters
    let resp = send(&mut stream, &mut reader, "{\"op\":\"stats\"}");
    assert_eq!(resp.req("ok").as_bool(), Some(true));
    assert_eq!(resp.req("shards").as_u64(), Some(2));
    assert_eq!(resp.req("shard_loads").as_arr().map(|a| a.len()), Some(2));
    assert_eq!(resp.req("dead_shards").as_u64(), Some(0));
    let jobs = resp.req("jobs");
    assert_eq!(jobs.req("completed").as_u64(), Some(2));
    assert_eq!(jobs.req("submitted").as_u64(), Some(2), "the bad submit never got an id");

    // v1 compat shim: generate still round-trips with its old shape
    let resp = send(
        &mut stream,
        &mut reader,
        "{\"op\":\"generate\",\"policy\":\"fora\",\"N\":4,\"seed\":9}",
    );
    assert_eq!(resp.req("ok").as_bool(), Some(true), "{resp:?}");
    assert!(resp.req("stats").req("speedup").as_f64().unwrap() > 2.0);
    assert!(resp.get("state").is_none(), "the v1 reply shape carries no state field");

    stream.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    let completed = server.join().unwrap();
    assert_eq!(completed, 3);
}
