//! Integration: lookahead-k speculative execution (DESIGN.md §16) on
//! the deterministic error-injection backend. The drift script decides
//! every verify and audit outcome in advance, so the accept-a-prefix
//! machinery is pinned exactly: a rejected run ratifies precisely the
//! engineered prefix j of k, `lookahead=1` is bitwise-identical to the
//! pre-lookahead engine, the adaptive k-ladder grows on scripted accept
//! streaks, a request parked mid-speculation round-trips through the
//! SPCK v3 codec at every tick boundary, and the spectral draft matches
//! a direct scalar DCT oracle.

use std::f32::consts::PI;
use std::sync::Arc;

use speca::cache::{Draft, TapHistory};
use speca::config::ModelConfig;
use speca::coordinator::state::{Completion, RequestCheckpoint, RequestSpec};
use speca::coordinator::{Admission, Engine, EngineConfig, JobMeta};
use speca::runtime::ModelBackend;
use speca::workload::parse_policy;
use speca::workload::scripted::ScriptedBackend;

/// Per-step rel error far below any threshold: every verify accepts.
const EASY: &[f32] = &[0.0005];
/// Alternating tiny/large drift: a mixed accept/reject trace.
const MIXED: &[f32] = &[0.001, 0.35];
/// One hard step (index 3) in an otherwise drift-free schedule: the
/// first k=4 run verifies at step 4 against refresh 0 and rejects with
/// e = 0.5, and its audit accepts exactly steps 1 and 2 (see
/// `a_rejected_run_ratifies_exactly_the_passing_prefix`).
const SPIKE: &[f32] = &[0.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];

fn scripted(drift: &[f32]) -> Arc<ScriptedBackend> {
    Arc::new(ScriptedBackend::new(ModelConfig::native_test(), drift))
}

fn spec(id: u64, depth: usize, desc: &str) -> RequestSpec {
    RequestSpec {
        id,
        cond: (id % 4) as i32,
        seed: 100 + id,
        policy: parse_policy(desc, depth).unwrap(),
        record_traj: false,
        meta: JobMeta::default(),
    }
}

/// The request run start-to-finish on one engine with no interruption —
/// the reference every park/resume variant must match bitwise.
fn run_uninterrupted(model: &Arc<ScriptedBackend>, s: RequestSpec) -> Completion {
    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(s);
    let mut done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    done.pop().unwrap()
}

/// Everything observable about a completion except wall-clock latency
/// must match exactly.
fn assert_bitwise(a: &Completion, b: &Completion, what: &str) {
    assert_eq!(a.id, b.id, "{what}: id");
    assert_eq!(a.policy_name, b.policy_name, "{what}: policy");
    assert_eq!(a.latent, b.latent, "{what}: final latent drifted");
    assert_eq!(a.stats.full_steps, b.stats.full_steps, "{what}: full steps");
    assert_eq!(a.stats.spec_steps, b.stats.spec_steps, "{what}: spec steps");
    assert_eq!(a.stats.rejects, b.stats.rejects, "{what}: rejects");
    assert_eq!(a.stats.verify_trace, b.stats.verify_trace, "{what}: verify trace");
    assert_eq!(a.stats.prefix_hist, b.stats.prefix_hist, "{what}: prefix histogram");
    assert_eq!(a.stats.flops.total(), b.stats.flops.total(), "{what}: booked FLOPs");
}

/// Park the engine's single in-flight request — mid-run boundaries are
/// legal park points, so (unlike the `tests/adaptive.rs` twin) no step
/// value is asserted here.
fn park_one(engine: &mut Engine<'_>) -> Box<RequestCheckpoint> {
    let mut units = engine.park_all();
    assert_eq!(units.len(), 1, "expected one in-flight request");
    let Some(Admission::Parked(ckpt)) = units.pop() else {
        panic!("park_all returned a fresh spec");
    };
    ckpt
}

/// ISSUE acceptance: `lookahead=1` (and the key left unset, which
/// defaults to 1) is bitwise-identical to the pre-lookahead engine —
/// same latent, same verify trace, same booked FLOPs — for both static
/// and adaptive requests on a mixed accept/reject script.
#[test]
fn lookahead_one_is_bitwise_identical_to_the_default() {
    let model = scripted(MIXED);
    let depth = model.entry().config.depth;
    for base in [
        "speca:N=5,O=1,tau0=0.05,beta=1,metric=l1",
        "speca:N=12,O=1,tau0=0.3,beta=1,metric=l1,adaptive=10",
    ] {
        let with_key = format!("{base},lookahead=1");
        let a = run_uninterrupted(&model, spec(0, depth, base));
        let b = run_uninterrupted(&model, spec(0, depth, &with_key));
        assert_bitwise(&a, &b, &format!("{base}: lookahead=1 vs unset"));
    }
}

/// ISSUE acceptance: with an engineered drift spike the first k=4 run
/// rejects at its verify point and the audit ratifies exactly the
/// j=2-of-3 intermediate prefix; the engine rolls the latent back to
/// the last accepted boundary, re-executes the rejected step densely,
/// and the remaining runs accept whole. Every observable — step
/// accounting, verify/audit trace, prefix histogram, final latent — is
/// pinned.
#[test]
fn a_rejected_run_ratifies_exactly_the_passing_prefix() {
    let desc = "speca:N=12,O=1,tau0=0.3,beta=1,draft=reuse,metric=l1,lookahead=4";
    let model = scripted(SPIKE);
    let depth = model.entry().config.depth;
    let c4 = run_uninterrupted(&model, spec(0, depth, desc));

    // step 0 refreshes (level 1); steps 1,2,3 speculate ahead; the
    // verify at step 4 sees e = 1 − level(0)/level(4) = 0.5 > τ = 0.3
    // and rejects; the audit replays the stored predictions: e(1) = 0,
    // e(2) = 0, e(3) = 0.5 → prefix j = 2. The rolled-back step 3 runs
    // densely in the same tick (second refresh, level 2), after which
    // the runs 4-7 and 8-11 verify at e = 0 and ratify whole.
    assert_eq!(c4.stats.full_steps, 2, "refresh at step 0 plus the rolled-back step 3");
    assert_eq!(c4.stats.spec_steps, 10, "all other steps speculate");
    assert_eq!(c4.stats.rejects, 1, "exactly the engineered rejection");
    assert_eq!(
        c4.stats.prefix_hist,
        vec![0, 0, 1, 0, 2],
        "one audited j=2 prefix, two whole k=4 runs"
    );
    assert_eq!(
        c4.stats.verify_trace,
        vec![
            (4, 0.5, 0.3),  // the rejected verify point
            (1, 0.0, 0.3),  // audit rows, ascending step order
            (2, 0.0, 0.3),
            (3, 0.5, 0.3),
            (7, 0.0, 0.3),  // the two whole-run verifies
            (11, 0.0, 0.3),
        ],
        "the verify + audit trace is pinned by the script"
    );

    // the k=1 engine walks the same accept/reject path step by step
    // (reject at step 3, dense re-execution, accepts elsewhere), so the
    // final latent must agree bitwise even though the traces differ
    let c1 = run_uninterrupted(
        &model,
        spec(0, depth, "speca:N=12,O=1,tau0=0.3,beta=1,draft=reuse,metric=l1,lookahead=1"),
    );
    assert_eq!(c1.stats.full_steps, 2, "k=1 rejects the same step densely");
    assert_eq!(c1.stats.rejects, 1);
    assert_eq!(c4.latent, c1.latent, "prefix rollback must land on the k=1 trajectory");
}

/// The adaptive k-ladder grows on scripted accept streaks: starting at
/// k=1, every [`speca::coordinator::adaptive::LOOK_GROW_AFTER`] (= 2)
/// consecutive accepted verifies buy one more step of run length, and
/// the prefix histogram records the longer runs as they appear.
#[test]
fn adaptive_k_ladder_grows_on_sustained_acceptance() {
    let desc = "speca:N=12,O=1,tau0=0.3,beta=1,draft=reuse,metric=l1,adaptive=10,lookahead=4";
    let model = scripted(EASY);
    let depth = model.entry().config.depth;
    let c = run_uninterrupted(&model, spec(0, depth, desc));
    assert_eq!(c.stats.full_steps, 1, "only the step-0 refresh is dense");
    assert_eq!(c.stats.spec_steps, 11, "every other step speculates");
    assert_eq!(c.stats.rejects, 0, "the easy script never rejects");
    // verifies at steps 1,2 (k=1, growing to 2), 4,6 (k=2, growing to
    // 3), 9 (k=3, growing pending), 11 (run cut to 2 by the end of the
    // schedule): runs of length 1,1,2,2,3,2
    assert_eq!(
        c.stats.prefix_hist,
        vec![0, 2, 3, 1, 0],
        "the ladder climbs 1 → 2 → 3 across the schedule"
    );
}

/// ISSUE acceptance: a lookahead-4 request parks and resumes bitwise at
/// *every* tick boundary — including mid-run boundaries with 1, 2 or 3
/// unratified speculated steps in flight — through the SPCK v3 byte
/// codec, on a different engine.
#[test]
fn spck_v3_round_trips_mid_speculation_at_every_boundary() {
    let desc = "speca:N=12,O=1,tau0=0.3,beta=1,draft=reuse,metric=l1,lookahead=4";
    let model = scripted(SPIKE);
    let depth = model.entry().config.depth;
    let reference = run_uninterrupted(&model, spec(0, depth, desc));
    // open-run length after each tick: three runs of aheads broken by
    // the audit tick (which nets zero step movement: rollback + dense
    // re-execution) and the accepted verify points
    let expect_run = [0usize, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3];
    for (b, &run) in expect_run.iter().enumerate().map(|(i, r)| (i + 1, r)) {
        let mut engine = Engine::new(model.clone(), EngineConfig::default());
        engine.submit(spec(0, depth, desc));
        for _ in 0..b {
            assert!(engine.tick().unwrap(), "engine idle before tick {b}");
        }
        assert_eq!(
            engine.speculation_depth(0),
            Some(run),
            "tick {b}: open-run depth while resident"
        );
        let ckpt = park_one(&mut engine);
        let policy = ckpt.spec.policy.clone();
        let meta = ckpt.spec.meta.clone();
        let bytes = ckpt.to_bytes();
        let decoded = RequestCheckpoint::from_bytes(&bytes, policy, meta)
            .expect("a parked mid-run image must decode");
        assert_eq!(decoded.to_bytes(), bytes, "tick {b}: codec not canonical");
        assert_eq!(decoded.look.len(), run, "tick {b}: in-flight run snapshots");
        let mut peer = Engine::new(model.clone(), EngineConfig::default());
        peer.submit_checkpoint(Box::new(decoded));
        let mut done = peer.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(peer.resumed, 1);
        assert_bitwise(&reference, &done.pop().unwrap(), &format!("resume at tick {b}"));
    }
}

/// The spectral draft's collapsed per-factor axpy sweep must match a
/// direct scalar oracle: reconstruct the chronological refresh
/// snapshots from the difference factors, take their DCT-II per
/// channel, damp coefficient n by 0.7ⁿ (the registry default) and
/// evaluate the basis at the fractional position p* = m + k/N past the
/// window.
#[test]
fn spectral_draft_matches_a_direct_dct_oracle() {
    let spectral = Draft::named("spectral").expect("spectral is a registry builtin");
    assert_eq!(spectral.name(), "spectral");
    // chronological refresh snapshots g₀ (oldest) .. g₂ (newest)
    let g = [
        vec![1.0f32, -2.0, 0.25, 8.0],
        vec![1.5f32, -1.0, 0.20, 6.5],
        vec![2.5f32, 0.5, 0.10, 5.75],
    ];
    let m = 2usize;
    let interval = 4.0f32;
    let damp = 0.7f32;
    // backward differences at the newest snapshot: Δ⁰ = g₂,
    // Δ¹ = g₂ − g₁, Δ² = g₂ − 2g₁ + g₀
    let d0 = g[2].clone();
    let d1: Vec<f32> = g[2].iter().zip(&g[1]).map(|(a, b)| a - b).collect();
    let d2: Vec<f32> =
        g[2].iter().zip(&g[1]).zip(&g[0]).map(|((a, b), c)| a - 2.0 * b + c).collect();
    let factors = [d0.clone(), d1, d2];
    let hist = TapHistory::new(&factors, m, interval);
    for k in [1.0f32, 2.0, 3.0, 6.0] {
        let mut out = vec![0.0f32; 4];
        spectral.predict_into(&hist, k, &mut out);
        let l = (m + 1) as f32;
        let pstar = m as f32 + k / interval;
        for c in 0..4 {
            let mut oracle = 0.0f32;
            for n in 0..=m {
                let coeff: f32 = (0..=m)
                    .map(|p| g[p][c] * (PI * n as f32 * (p as f32 + 0.5) / l).cos())
                    .sum();
                let scale = if n == 0 { 0.5 } else { damp.powi(n as i32) };
                oracle += scale * coeff * (PI * n as f32 * (pstar + 0.5) / l).cos();
            }
            oracle *= 2.0 / l;
            assert!(
                (out[c] - oracle).abs() <= 1e-4 * (1.0 + oracle.abs()),
                "k={k} channel {c}: draft {} vs oracle {oracle}",
                out[c]
            );
        }
    }
    // the DCT weights sum to 1 at every horizon, so a constant
    // trajectory is predicted exactly (up to f32 summation noise)
    let flat = [vec![3.0f32; 2], vec![0.0f32; 2], vec![0.0f32; 2]];
    let fh = TapHistory::new(&flat, m, interval);
    let mut out = vec![0.0f32; 2];
    spectral.predict_into(&fh, 5.0, &mut out);
    for v in &out {
        assert!((v - 3.0).abs() <= 1e-5, "constant trajectory must be DC-exact, got {v}");
    }
    // with no observed differences the draft degrades to feature reuse
    let h0 = TapHistory::new(&factors, 0, interval);
    let mut out = vec![0.0f32; 4];
    spectral.predict_into(&h0, 3.0, &mut out);
    assert_eq!(out, d0, "usable order 0 must reuse the newest snapshot");
}
