//! Kernel parity suite (DESIGN.md §12): every blocked kernel against the
//! retained scalar reference, ULP-bounded, across shapes that are *not*
//! multiples of the MR×NR tile (remainder rows, padded panel columns,
//! heads that don't divide the model width), every prologue/epilogue
//! fusion, and — end to end — every `NativeArch` preset through both
//! [`KernelMode`]s of the native backend.
//!
//! Tolerances: element comparisons pass when the values are within
//! `max_ulps` representable f32s of each other *or* within a small
//! absolute slack (the two paths sum in different orders, so exact-zero
//! cancellations can land on opposite sides of zero; an absolute
//! backstop is the standard escape hatch for that case).

use speca::config::ModelConfig;
use speca::runtime::kernels::{
    self, scalar, Epilogue, Gemm, KernelMode, MatA, MatB, PackBufs, Prologue,
};
use speca::runtime::{ModelBackend, NativeBackend};
use speca::util::rng::Rng;

/// Map f32 bit patterns onto a monotonic integer line so the distance
/// between two floats counts representable values between them.
fn ulp_index(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        -((b & 0x7fff_ffff) as i64)
    } else {
        b as i64
    }
}

fn ulp_diff(a: f32, b: f32) -> i64 {
    (ulp_index(a) - ulp_index(b)).abs()
}

/// Element-wise comparison: within `max_ulps` representable values, or
/// within `abs_slack` absolutely (cancellation backstop).
fn assert_close(tag: &str, got: &[f32], want: &[f32], max_ulps: i64, abs_slack: f32) {
    assert_eq!(got.len(), want.len(), "{tag}: length mismatch");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert!(g.is_finite(), "{tag}[{i}]: non-finite {g}");
        let ok = ulp_diff(g, w) <= max_ulps || (g - w).abs() <= abs_slack;
        assert!(ok, "{tag}[{i}]: got {g}, want {w}, ulps {}", ulp_diff(g, w));
    }
}

/// Shapes deliberately off the MR=4 / NR=16 grid: remainder row tiles
/// (m mod 4 ≠ 0), padded panel columns (n mod 16 ≠ 0), k of every size.
const SHAPES: &[(usize, usize, usize)] =
    &[(1, 1, 1), (2, 3, 5), (3, 7, 17), (5, 24, 33), (17, 31, 47), (16, 16, 16), (1, 13, 40)];

#[test]
fn gemm_parity_all_fusions_odd_shapes() {
    let mut rng = Rng::new(0xD15EA5E);
    for &(m, k, n) in SHAPES {
        let a = rng.normal_f32s(m * k);
        let w = rng.normal_f32s(k * n);
        let bias = rng.normal_f32s(n);
        let shift_k = rng.normal_f32s(k);
        let scale_k = rng.normal_f32s(k);
        let shift_n = rng.normal_f32s(n);
        let scale_n = rng.normal_f32s(n);
        let gate = rng.normal_f32s(n);
        let rows = rng.normal_f32s(m * n);
        let base = rng.normal_f32s(m * n);
        // oracle-side prologue: modulate A before the naive matmul
        let mut a_mod = a.clone();
        for i in 0..m {
            for kk in 0..k {
                a_mod[i * k + kk] = a[i * k + kk] * (1.0 + scale_k[kk]) + shift_k[kk];
            }
        }
        let mut pa = vec![0.0f32; m * k];
        let mut pb = vec![0.0f32; k * kernels::NR];
        for pro_mod in [false, true] {
            let a_oracle = if pro_mod { &a_mod } else { &a };
            let mut raw = vec![0.0f32; m * n];
            scalar::matmul_add(a_oracle, &w, &bias, m, k, n, &mut raw);
            for epi_name in ["none", "silu", "modulate", "gated", "addrows"] {
                let mut want = raw.clone();
                match epi_name {
                    "silu" => {
                        for v in want.iter_mut() {
                            *v = scalar::silu(*v);
                        }
                    }
                    "modulate" => {
                        scalar::modulate(&mut want, &shift_n, &scale_n, m, n);
                    }
                    "gated" => {
                        for i in 0..m {
                            for j in 0..n {
                                want[i * n + j] = base[i * n + j] + gate[j] * raw[i * n + j];
                            }
                        }
                    }
                    "addrows" => {
                        for (v, r) in want.iter_mut().zip(&rows) {
                            *v += r;
                        }
                    }
                    _ => {}
                }
                let epilogue = match epi_name {
                    "silu" => Epilogue::Silu,
                    "modulate" => Epilogue::Modulate { shift: &shift_n, scale: &scale_n },
                    "gated" => Epilogue::GatedResidual { gate: &gate },
                    "addrows" => Epilogue::AddRows { rows: &rows, rs: n },
                    _ => Epilogue::None,
                };
                let prologue = if pro_mod {
                    Prologue::Modulate { shift: &shift_k, scale: &scale_k }
                } else {
                    Prologue::None
                };
                let mut got = vec![0.0f32; m * n];
                if epi_name == "gated" {
                    got.copy_from_slice(&base); // residual accumulates in place
                }
                Gemm {
                    m,
                    k,
                    n,
                    a: MatA::dense(&a, k),
                    b: MatB::dense(&w, n),
                    prologue,
                    bias: Some(&bias),
                    epilogue,
                }
                .run(&mut got, n, &mut PackBufs { a: &mut pa, b: &mut pb });
                let tag = format!("gemm({m},{k},{n}) pro={pro_mod} epi={epi_name}");
                assert_close(&tag, &got, &want, 256, 1e-4);
            }
        }
    }
}

#[test]
fn gemm_parity_without_bias() {
    let mut rng = Rng::new(7);
    let (m, k, n) = (6, 11, 21);
    let a = rng.normal_f32s(m * k);
    let w = rng.normal_f32s(k * n);
    let zeros = vec![0.0f32; n];
    let mut want = vec![0.0f32; m * n];
    scalar::matmul_add(&a, &w, &zeros, m, k, n, &mut want);
    let mut pa = vec![0.0f32; m * k];
    let mut pb = vec![0.0f32; k * kernels::NR];
    let mut got = vec![0.0f32; m * n];
    Gemm {
        m,
        k,
        n,
        a: MatA::dense(&a, k),
        b: MatB::dense(&w, n),
        prologue: Prologue::None,
        bias: None,
        epilogue: Epilogue::None,
    }
    .run(&mut got, n, &mut PackBufs { a: &mut pa, b: &mut pb });
    assert_close("gemm no-bias", &got, &want, 256, 1e-4);
}

#[test]
fn layer_norm_parity_odd_widths() {
    let mut rng = Rng::new(0xBADCAB);
    for &(t, d) in &[(1usize, 3usize), (2, 5), (5, 17), (16, 24), (3, 33), (7, 101)] {
        let x = rng.normal_f32s(t * d);
        let mut want = vec![0.0f32; t * d];
        let mut got = vec![0.0f32; t * d];
        scalar::layer_norm(&x, &mut want, t, d);
        kernels::layer_norm(&x, &mut got, t, d);
        assert_close(&format!("layer_norm({t},{d})"), &got, &want, 512, 1e-4);
    }
}

#[test]
fn attention_parity_odd_heads() {
    let mut rng = Rng::new(0xA77);
    // (tokens, d, heads): dh = 1 edge, ragged splits (heads·dh < d),
    // tile-multiple and off-grid token counts
    for &(t, d, h) in
        &[(1usize, 4usize, 1usize), (3, 5, 5), (5, 9, 2), (7, 10, 3), (16, 24, 4), (13, 12, 4)]
    {
        let qkv = rng.normal_f32s(t * 3 * d);
        let mut want = vec![0.0f32; t * d];
        let mut probs = vec![0.0f32; t];
        scalar::attention(&qkv, t, d, h, &mut want, &mut probs);
        let mut got = vec![0.0f32; t * d];
        let mut scores = vec![0.0f32; t * t];
        let kmax = t.max(d / h);
        let mut pa = vec![0.0f32; t * kmax];
        let mut pb = vec![0.0f32; kmax * kernels::NR];
        kernels::attention(
            &qkv,
            t,
            d,
            h,
            &mut got,
            &mut scores,
            &mut PackBufs { a: &mut pa, b: &mut pb },
        );
        assert_close(&format!("attention({t},{d},{h})"), &got, &want, 4096, 1e-4);
    }
}

/// End-to-end: both kernel modes through the public `ModelBackend`
/// surface on every preset, eps and all boundary taps.
#[test]
fn forward_parity_across_presets() {
    let presets = [
        ModelConfig::native_dit(),
        ModelConfig::native_flux(),
        ModelConfig::native_video(),
        ModelConfig::native_test(),
    ];
    for cfg in presets {
        let name = cfg.name.clone();
        let blocked = NativeBackend::seeded(cfg.clone(), 99).with_kernel_mode(KernelMode::Blocked);
        let reference = NativeBackend::seeded(cfg, 99).with_kernel_mode(KernelMode::Scalar);
        let c = &blocked.entry().config;
        let mut rng = Rng::new(31);
        let x = rng.normal_f32s(2 * c.latent_dim);
        let t = vec![c.serve_steps as f32, 1.0];
        let y = vec![1i32, 3];
        let (eb, bb) = blocked.full(2, &x, &t, &y, false).unwrap();
        let (es, bs) = reference.full(2, &x, &t, &y, false).unwrap();
        for (i, (a, b)) in eb.data.iter().zip(&es.data).enumerate() {
            let ok = (a - b).abs() <= 1e-3 + 1e-3 * b.abs();
            assert!(ok, "{name} eps[{i}]: blocked {a} vs scalar {b}");
        }
        for (i, (a, b)) in bb.data.iter().zip(&bs.data).enumerate() {
            let ok = (a - b).abs() <= 1e-3 + 1e-3 * b.abs();
            assert!(ok, "{name} boundary[{i}]: blocked {a} vs scalar {b}");
        }
        // the decomposed entry points ride the same kernels
        let feat = c.tokens * c.dim;
        let blk = blocked.block(1, 0, &bb.data[..feat], &t[..1], &y[..1]).unwrap();
        let blk_s = reference.block(1, 0, &bs.data[..feat], &t[..1], &y[..1]).unwrap();
        for (i, (a, b)) in blk.data.iter().zip(&blk_s.data).enumerate() {
            let ok = (a - b).abs() <= 1e-3 + 1e-3 * b.abs();
            assert!(ok, "{name} block[{i}]: blocked {a} vs scalar {b}");
        }
    }
}
