//! Integration: the SpeCa engine end-to-end — policy behaviour,
//! conservation invariants, batching transparency, accept/reject
//! bookkeeping, sample-adaptive allocation.
//!
//! Every invariant is a check function over `&dyn ModelBackend`. The
//! top-level tests assert them unconditionally against the zero-artifact
//! [`NativeBackend`]; the `pjrt` module re-runs the identical checks over
//! AOT artifacts when built with `--features pjrt` (skipping, as before,
//! if `make artifacts` has not produced them).

use speca::config::ModelConfig;
use speca::coordinator::batcher::BatchStrategy;
use speca::coordinator::policy::{ErrorMetric, Policy};
use speca::coordinator::{Completion, Engine, EngineConfig};
use speca::runtime::{ModelBackend, NativeBackend};
use speca::workload::{batch_requests, parse_policy};

fn native_model() -> NativeBackend {
    NativeBackend::seeded(ModelConfig::native_test(), 0x5EED)
}

fn run(
    model: &dyn ModelBackend,
    desc: &str,
    n: usize,
    seed: u64,
    strategy: BatchStrategy,
) -> Vec<Completion> {
    let policy = parse_policy(desc, model.entry().config.depth).unwrap();
    let mut engine = Engine::from_ref(
        model,
        EngineConfig { max_inflight: 4, strategy, use_pallas: false },
    );
    for r in batch_requests(n, model.entry().config.num_classes, &policy, seed, false) {
        engine.submit(r);
    }
    let mut done = engine.run_to_completion().unwrap();
    done.sort_by_key(|c| c.id);
    done
}

/// Every request must account for exactly serve_steps actions.
fn check_step_conservation(model: &dyn ModelBackend) {
    let steps = model.entry().config.serve_steps;
    for desc in [
        "full",
        "steps:keep=10",
        "fora:N=6",
        "teacache:l=0.6",
        "toca:N=8,R=0.9",
        "duca:N=8,R=0.9",
        "taylorseer:N=5,O=2",
        "speca:N=5,O=2,tau0=0.3,beta=0.05",
        "speca:N=5,O=2,tau0=0.01,beta=0.05", // strict: many rejects
    ] {
        let done = run(model, desc, 3, 7, BatchStrategy::Binary);
        assert_eq!(done.len(), 3, "{desc}");
        for c in &done {
            let s = &c.stats;
            let total = s.full_steps
                + s.spec_steps
                + s.skip_steps
                + s.blend_steps
                + s.elided_steps;
            assert_eq!(total, steps, "{desc}: step accounting");
            // rejects always coincide with fallback full computes
            assert!(s.rejects <= s.full_steps, "{desc}");
            assert!(c.latent.iter().all(|v| v.is_finite()), "{desc}: non-finite latent");
        }
    }
}

/// full-policy engine output must equal a bucket-1 manual loop (the
/// engine adds no numerical noise).
fn check_full_policy_is_reference_quality(model: &dyn ModelBackend) {
    let entry = model.entry();
    let done = run(model, "full", 2, 3, BatchStrategy::Binary);

    // manual replay of request 0
    let spec = batch_requests(2, entry.config.num_classes, &Policy::Full, 3, false);
    let mut rng = speca::util::rng::Rng::new(spec[0].seed);
    let mut x = rng.normal_f32s(entry.config.latent_dim);
    let y = vec![spec[0].cond];
    let sched = &entry.schedule;
    for i in 0..entry.config.serve_steps {
        let t = vec![sched.t_model[i]];
        let (eps, _) = model.full(1, &x, &t, &y, false).unwrap();
        match sched.kind {
            speca::config::ScheduleKind::Ddim => {
                speca::sampler::ddim_step(&mut x, &eps.data, sched.ab_t[i], sched.ab_prev[i])
            }
            speca::config::ScheduleKind::RectifiedFlow => {
                speca::sampler::rf_step(&mut x, &eps.data, sched.dt)
            }
        }
    }
    let e = ErrorMetric::L2.eval(&done[0].latent, &x);
    assert!(e < 1e-4, "engine-vs-manual rel err {e}");
}

/// binary vs pad-up batching must give identical outputs per request.
fn check_batching_strategy_is_transparent(model: &dyn ModelBackend) {
    let a = run(model, "speca:N=5,O=2,tau0=0.3,beta=0.05", 3, 11, BatchStrategy::Binary);
    let b = run(model, "speca:N=5,O=2,tau0=0.3,beta=0.05", 3, 11, BatchStrategy::PadUp);
    for (ca, cb) in a.iter().zip(&b) {
        let e = ErrorMetric::L2.eval(&ca.latent, &cb.latent);
        assert!(e < 1e-4, "req {}: strategies diverge ({e})", ca.id);
        assert_eq!(ca.stats.full_steps, cb.stats.full_steps);
        assert_eq!(ca.stats.rejects, cb.stats.rejects);
    }
}

/// Tight τ0 ⇒ rejects dominate ⇒ cost near full compute; loose τ0 ⇒
/// acceptance near the interval bound.
fn check_speca_threshold_controls_acceptance(model: &dyn ModelBackend) {
    let strict = run(model, "speca:N=5,O=2,tau0=0.001,beta=1.0", 2, 5, BatchStrategy::Binary);
    let loose = run(model, "speca:N=5,O=2,tau0=50.0,beta=1.0", 2, 5, BatchStrategy::Binary);
    let strict_spec: usize = strict.iter().map(|c| c.stats.spec_steps).sum();
    let loose_spec: usize = loose.iter().map(|c| c.stats.spec_steps).sum();
    assert!(loose_spec > strict_spec, "loose {loose_spec} vs strict {strict_spec}");
    let strict_rej: usize = strict.iter().map(|c| c.stats.rejects).sum();
    assert!(strict_rej > 0, "strict threshold should reject");
    // with τ=50 everything verifiable is accepted
    let loose_rej: usize = loose.iter().map(|c| c.stats.rejects).sum();
    assert_eq!(loose_rej, 0);
}

/// The paper's core claim in miniature: at the same refresh interval,
/// SpeCa's verified trajectory stays at least as close to the reference as
/// unverified TaylorSeer.
fn check_speca_beats_taylorseer_at_matched_budget(model: &dyn ModelBackend) {
    let n = 4;
    let reference = run(model, "full", n, 21, BatchStrategy::Binary);
    let taylor = run(model, "taylorseer:N=9,O=2", n, 21, BatchStrategy::Binary);
    let speca = run(model, "speca:N=9,O=2,tau0=0.3,beta=0.05", n, 21, BatchStrategy::Binary);
    let mean_err = |runs: &[Completion]| -> f64 {
        runs.iter()
            .zip(&reference)
            .map(|(c, r)| ErrorMetric::L2.eval(&c.latent, &r.latent))
            .sum::<f64>()
            / n as f64
    };
    let te = mean_err(&taylor);
    let se = mean_err(&speca);
    assert!(
        se <= te + 1e-9,
        "speca err {se} should not exceed taylorseer err {te}"
    );
}

/// Different samples should receive different computation (paper §4.3)
/// under a mid-range threshold.
fn check_sample_adaptive_allocation_varies(model: &dyn ModelBackend) {
    let done = run(model, "speca:N=8,O=2,tau0=0.12,beta=0.3", 6, 31, BatchStrategy::Binary);
    // the acceptance signal is sample-dependent: per-request mean verify
    // errors must differ (this is what drives the paper's per-sample accel
    // distribution at scale)
    let mean_errs: Vec<f64> = done
        .iter()
        .map(|c| {
            let tr = &c.stats.verify_trace;
            tr.iter().map(|(_, e, _)| *e).sum::<f64>() / tr.len().max(1) as f64
        })
        .collect();
    let min = mean_errs.iter().cloned().fold(f64::MAX, f64::min);
    let max = mean_errs.iter().cloned().fold(f64::MIN, f64::max);
    assert!(
        max > min + 1e-9,
        "expected sample-dependent verification errors, got {mean_errs:?}"
    );
    // and every request logged a full verification trace
    assert!(done.iter().all(|c| !c.stats.verify_trace.is_empty()));
}

/// Eq. 5/6: within one speculative run, once a step is rejected no later
/// speculative step may be recorded before the next refresh.
fn check_verify_trace_is_prefix_consistent(model: &dyn ModelBackend) {
    let done = run(model, "speca:N=6,O=2,tau0=0.05,beta=0.5", 3, 17, BatchStrategy::Binary);
    for c in &done {
        for w in c.stats.verify_trace.windows(2) {
            let (s0, e0, t0) = w[0];
            let (s1, _, _) = w[1];
            assert!(s1 > s0, "verify trace out of order");
            if e0 > t0 {
                // rejection at s0 ⇒ s0 became a full step; the next
                // speculative step needs at least one step of spacing
                assert!(s1 >= s0 + 1);
            }
        }
    }
}

fn check_mixed_policies_coexist(model: &dyn ModelBackend) {
    let entry = model.entry();
    let mut engine = Engine::from_ref(model, EngineConfig::default());
    let descs = ["full", "fora:N=5", "speca:N=5,O=2,tau0=0.3,beta=0.05", "taylorseer:N=5,O=2"];
    for (i, d) in descs.iter().enumerate() {
        let policy = parse_policy(d, entry.config.depth).unwrap();
        engine.submit(speca::coordinator::RequestSpec {
            id: i as u64,
            cond: i as i32 % entry.config.num_classes as i32,
            seed: 100 + i as u64,
            policy,
            record_traj: false,
            meta: speca::coordinator::JobMeta::default(),
        });
    }
    let done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 4);
    let names: std::collections::BTreeSet<String> =
        done.iter().map(|c| c.policy_name.clone()).collect();
    assert_eq!(names.len(), 4);
}

// --- native backend: every invariant asserts unconditionally --------------

#[test]
fn step_conservation_across_policies() {
    check_step_conservation(&native_model());
}

#[test]
fn full_policy_is_reference_quality() {
    check_full_policy_is_reference_quality(&native_model());
}

#[test]
fn batching_strategy_is_transparent() {
    check_batching_strategy_is_transparent(&native_model());
}

#[test]
fn speca_threshold_controls_acceptance() {
    check_speca_threshold_controls_acceptance(&native_model());
}

#[test]
fn speca_beats_taylorseer_at_matched_budget() {
    check_speca_beats_taylorseer_at_matched_budget(&native_model());
}

#[test]
fn sample_adaptive_allocation_varies() {
    check_sample_adaptive_allocation_varies(&native_model());
}

#[test]
fn verify_trace_is_prefix_consistent() {
    check_verify_trace_is_prefix_consistent(&native_model());
}

#[test]
fn mixed_policies_coexist() {
    check_mixed_policies_coexist(&native_model());
}

/// The engine must also run a rectified-flow schedule end-to-end (the
/// flux/video simulated backbones use RF) — same tiny geometry as the
/// DDIM fixture to keep the debug-profile test fast.
#[test]
fn rectified_flow_schedule_end_to_end() {
    let mut cfg = ModelConfig::native_test();
    cfg.name = "rf-test".to_string();
    cfg.schedule_kind = speca::config::ScheduleKind::RectifiedFlow;
    cfg.serve_steps = 10;
    let model = NativeBackend::seeded(cfg, 0xF10F);
    check_step_conservation(&model);
    check_full_policy_is_reference_quality(&model);
}

// --- PJRT backend: same checks, gated on feature + artifacts --------------

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use speca::config::Manifest;
    use speca::runtime::{ModelRuntime, Runtime};

    fn with_artifacts(f: impl FnOnce(&dyn ModelBackend)) {
        let dir = speca::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        let manifest = Manifest::load(&dir).expect("manifest loads");
        let entry = manifest.model("dit-sim").unwrap();
        let rt = Runtime::cpu().unwrap();
        let model = ModelRuntime::load(&rt, entry).unwrap();
        f(&model);
    }

    #[test]
    fn step_conservation_across_policies() {
        with_artifacts(check_step_conservation);
    }

    #[test]
    fn full_policy_is_reference_quality() {
        with_artifacts(check_full_policy_is_reference_quality);
    }

    #[test]
    fn batching_strategy_is_transparent() {
        with_artifacts(check_batching_strategy_is_transparent);
    }

    #[test]
    fn speca_threshold_controls_acceptance() {
        with_artifacts(check_speca_threshold_controls_acceptance);
    }

    #[test]
    fn speca_beats_taylorseer_at_matched_budget() {
        with_artifacts(check_speca_beats_taylorseer_at_matched_budget);
    }

    #[test]
    fn sample_adaptive_allocation_varies() {
        with_artifacts(check_sample_adaptive_allocation_varies);
    }

    #[test]
    fn verify_trace_is_prefix_consistent() {
        with_artifacts(check_verify_trace_is_prefix_consistent);
    }

    #[test]
    fn mixed_policies_coexist() {
        with_artifacts(check_mixed_policies_coexist);
    }
}
