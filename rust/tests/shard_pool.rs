//! Integration: the sharded engine pool. The load-bearing guarantee is
//! *parity* — a K-request workload must produce identical per-request
//! completions (accept/reject decisions, step counts, outputs) on 1 shard
//! and on N shards, so sharding is a pure throughput win with no semantic
//! drift. Also covered: least-loaded routing under skewed request sizes,
//! mid-flight decay of the expected-work gauges, the sharded allocation
//! probe (shared workspace/result pools stop growing at peak
//! concurrency), pool stats aggregation, and clean shutdown (drain and
//! halt) with requests in flight.

use std::sync::Arc;
use std::time::Duration;

use speca::config::{ModelConfig, ModelEntry};
use speca::coordinator::state::{Completion, RequestSpec};
use speca::coordinator::{
    EngineConfig, EngineShardPool, JobEvent, JobMeta, PoolConfig, RouterPolicy,
};
use speca::runtime::native::{synthetic_entry, NativeArch};
use speca::runtime::{ModelBackend, NativeBackend};
use speca::tensor::Tensor;
use speca::workload::parse_policy;

fn pool_config(shards: usize) -> PoolConfig {
    PoolConfig {
        shards,
        router: RouterPolicy::LeastLoaded,
        engine: EngineConfig::default(),
        steal: false,
    }
}

/// Mixed-policy workload with per-request ids/seeds/conds.
fn workload(depth: usize, classes: usize) -> Vec<RequestSpec> {
    let descs = [
        "speca:N=5,O=2,tau0=0.3,beta=0.05",
        "speca:N=5,O=2,tau0=0.01,beta=0.05", // strict: rejects happen
        "taylorseer:N=5,O=2",
        "fora:N=4",
        "full",
        "steps:keep=6",
        "speca:N=4,O=1,tau0=0.5,beta=0.1",
        "teacache:l=0.6",
    ];
    descs
        .iter()
        .enumerate()
        .map(|(i, d)| RequestSpec {
            id: i as u64,
            cond: (i % classes) as i32,
            seed: 1000 + i as u64,
            policy: parse_policy(d, depth).unwrap(),
            record_traj: false,
            meta: JobMeta::default(),
        })
        .collect()
}

/// Run the same mixed workload through an N-shard pool; completions
/// sorted by request id.
fn run_workload(model: &Arc<NativeBackend>, shards: usize) -> Vec<Completion> {
    let depth = model.entry().config.depth;
    let classes = model.entry().config.num_classes;
    let pool = EngineShardPool::new(model.clone(), pool_config(shards));
    for spec in workload(depth, classes) {
        pool.submit(spec).unwrap();
    }
    let out = pool.shutdown(true).unwrap();
    let mut completions = out.completions;
    completions.sort_by_key(|c| c.id);
    completions
}

#[test]
fn one_vs_four_shard_parity() {
    let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 0x5EED));
    let one = run_workload(&model, 1);
    let four = run_workload(&model, 4);
    assert_eq!(one.len(), 8);
    assert_eq!(four.len(), 8);
    for (a, b) in one.iter().zip(&four) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.policy_name, b.policy_name);
        // outputs: bitwise-identical latents (native batching transparency
        // makes per-request math independent of co-batched neighbours)
        assert_eq!(a.latent, b.latent, "request {} latent drifted across shard counts", a.id);
        // step accounting: identical plan execution
        let (sa, sb) = (&a.stats, &b.stats);
        assert_eq!(sa.full_steps, sb.full_steps, "request {}", a.id);
        assert_eq!(sa.spec_steps, sb.spec_steps, "request {}", a.id);
        assert_eq!(sa.skip_steps, sb.skip_steps, "request {}", a.id);
        assert_eq!(sa.blend_steps, sb.blend_steps, "request {}", a.id);
        assert_eq!(sa.elided_steps, sb.elided_steps, "request {}", a.id);
        // accept/reject decisions: identical verification traces
        assert_eq!(sa.rejects, sb.rejects, "request {}", a.id);
        assert_eq!(sa.verify_trace, sb.verify_trace, "request {}", a.id);
        // booked FLOPs are per-sample (table[B]/B with linear tables), so
        // they must not depend on how requests were co-batched either
        assert_eq!(sa.flops.total(), sb.flops.total(), "request {}", a.id);
    }
}

#[test]
fn shard_counts_between_one_and_four_agree() {
    // 2 and 3 shards (uneven split) must match the 1-shard reference too
    let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 0xA11CE));
    let reference = run_workload(&model, 1);
    for shards in [2usize, 3] {
        let got = run_workload(&model, shards);
        for (a, b) in reference.iter().zip(&got) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.latent, b.latent, "{shards} shards, request {}", a.id);
            assert_eq!(a.stats.rejects, b.stats.rejects);
        }
    }
}

// ---------------------------------------------------------------------------
// Routing + shutdown behaviour over a slow deterministic stub backend
// ---------------------------------------------------------------------------

/// Zero-math backend whose full pass sleeps: makes request lifetimes long
/// and measurable so routing/shutdown interleavings are deterministic.
struct SlowBackend {
    entry: ModelEntry,
    delay: Duration,
}

impl SlowBackend {
    fn new(delay_ms: u64) -> SlowBackend {
        SlowBackend {
            entry: synthetic_entry(&ModelConfig::native_test(), &NativeArch::default()),
            delay: Duration::from_millis(delay_ms),
        }
    }
}

impl ModelBackend for SlowBackend {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn kind(&self) -> &'static str {
        "slow-stub"
    }

    fn supports(&self, entry_point: &str) -> bool {
        matches!(entry_point, "full" | "full_eps" | "block" | "head")
    }

    fn warmup(&self, _e: &[&str], _b: &[usize]) -> anyhow::Result<()> {
        Ok(())
    }

    fn full(
        &self,
        bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
        _pallas: bool,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        std::thread::sleep(self.delay);
        let c = &self.entry.config;
        Ok((
            Tensor::zeros(vec![bucket, c.latent_dim]),
            Tensor::zeros(vec![c.depth + 1, bucket, c.tokens, c.dim]),
        ))
    }

    fn full_eps(
        &self,
        bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        std::thread::sleep(self.delay);
        Ok(Tensor::zeros(vec![bucket, self.entry.config.latent_dim]))
    }

    fn block(
        &self,
        bucket: usize,
        _layer: i32,
        _feat: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        let c = &self.entry.config;
        Ok(Tensor::zeros(vec![bucket, c.tokens, c.dim]))
    }

    fn head(&self, bucket: usize, _f: &[f32], _t: &[f32], _y: &[i32]) -> anyhow::Result<Tensor> {
        Ok(Tensor::zeros(vec![bucket, self.entry.config.latent_dim]))
    }
}

fn slow_spec(id: u64, depth: usize, desc: &str) -> RequestSpec {
    RequestSpec {
        id,
        cond: 0,
        seed: id,
        policy: parse_policy(desc, depth).unwrap(),
        record_traj: false,
        meta: JobMeta::default(),
    }
}

#[test]
fn least_loaded_routing_skews_toward_idle_shards() {
    // full-policy requests occupy a shard for ~steps × delay, so the load
    // gauge is a faithful busy signal at submission time
    let model = Arc::new(SlowBackend::new(5));
    let depth = model.entry().config.depth;
    let mut pool = EngineShardPool::new(model.clone(), pool_config(2));
    let rx = pool.take_event_rx().unwrap();

    // heavy request (12 full steps) → shard 0 (all idle, lowest index)
    let s0 = pool.submit(slow_spec(0, depth, "full")).unwrap();
    assert_eq!(s0, 0);
    // cheap request (2 kept steps, rest elided) → least-loaded picks shard 1
    let s1 = pool.submit(slow_spec(1, depth, "steps:keep=2")).unwrap();
    assert_eq!(s1, 1);
    // both shards hold one request → [1, 1] ties to the lowest index
    let s2 = pool.submit(slow_spec(2, depth, "steps:keep=2")).unwrap();
    assert_eq!(s2, 0);

    // wait for the first cheap request to finish; the heavy one (60 ms of
    // sleeps minimum) is still running, so shard 1 is idle again. The
    // event stream now carries lifecycle chatter (Admitted / Progress)
    // around the completions — skip it.
    let first_done = loop {
        match rx.recv_timeout(Duration::from_secs(20)).expect("an event") {
            JobEvent::Completed(c) => break c,
            JobEvent::Aborted { id, error } => panic!("request {id} aborted: {error}"),
            _ => {}
        }
    };
    assert_eq!(first_done.id, 1, "the cheap request on the idle shard finishes first");
    let s3 = pool.submit(slow_spec(3, depth, "steps:keep=2")).unwrap();
    assert_eq!(s3, 1, "least-loaded must route to the drained shard");

    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.stats.completed, 4);
    // the event stream was taken, so the other 3 completions sit on it
    // (shutdown already joined every worker: the channel is fully buffered)
    let mut leftover = Vec::new();
    while let Ok(ev) = rx.try_recv() {
        match ev {
            JobEvent::Completed(c) => leftover.push(c.id),
            JobEvent::Aborted { id, error } => panic!("request {id} aborted: {error}"),
            _ => {}
        }
    }
    leftover.sort_unstable();
    assert_eq!(leftover, vec![0, 2, 3]);
}

#[test]
fn least_loaded_weighs_expected_work_not_request_counts() {
    // Skewed load: one heavy job (full policy, ~12 slow steps) with a
    // large service-time hint vs cheap jobs (2 kept steps) with small
    // hints — exactly the hints the JobManager's per-policy EWMA stamps.
    // Count-based routing would tie [1 req, 1 req] and pick shard 0;
    // work-weighted routing must keep routing cheap work to the shard
    // whose expected *remaining* work is smaller.
    let model = Arc::new(SlowBackend::new(3));
    let depth = model.entry().config.depth;
    let pool = EngineShardPool::new(model, pool_config(2));
    let router = pool.router();

    let mut heavy = slow_spec(0, depth, "full");
    heavy.meta.cost_hint = 60.0;
    assert_eq!(pool.submit(heavy).unwrap(), 0, "first submit lands on the idle lowest index");

    let mut cheap = slow_spec(1, depth, "steps:keep=2");
    cheap.meta.cost_hint = 5.0;
    assert_eq!(pool.submit(cheap).unwrap(), 1, "second submit avoids the busy shard");

    // both shards now hold one request — raw counts tie, expected work
    // does not (60 ms vs ≤5 ms): the cheap backlog must win
    let mut cheap2 = slow_spec(2, depth, "steps:keep=2");
    cheap2.meta.cost_hint = 5.0;
    assert_eq!(
        pool.submit(cheap2).unwrap(),
        1,
        "work-weighted least-loaded must prefer the cheap backlog over the request-count tie"
    );
    // the router's gauges expose the skew (shard 0 ≥ 60000 µ-units)
    let work = router.work_us();
    assert!(work[0] >= 60_000, "heavy hint booked on shard 0: {work:?}");

    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.completions.len(), 3);
}

#[test]
fn work_gauge_decays_as_progress_arrives() {
    // A heavy hinted request books its full cost at submit; the shard
    // worker then decays the booking linearly as serve steps complete
    // (`decay_weight`) — without anyone consuming the event stream — so
    // least-loaded routing sees remaining work shrink mid-flight instead
    // of only at completion.
    let model = Arc::new(SlowBackend::new(5));
    let depth = model.entry().config.depth;
    let pool = EngineShardPool::new(model, pool_config(1));
    let router = pool.router();

    let mut heavy = slow_spec(0, depth, "full");
    heavy.meta.cost_hint = 60.0; // books 60_000 µ-units on shard 0
    pool.submit(heavy).unwrap();

    // sample the gauge until the terminal release zeroes it; the smallest
    // nonzero sample witnesses mid-flight decay (each of the 12 serve
    // steps sleeps 5 ms, so intermediate values are visible for ~55 ms)
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    let mut min_seen = u64::MAX;
    loop {
        let w = router.work_us()[0];
        if w == 0 {
            break;
        }
        min_seen = min_seen.min(w);
        assert!(std::time::Instant::now() < deadline, "request never completed");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(min_seen < 60_000, "gauge never decayed below the admission booking: {min_seen}");
    assert!(min_seen >= 1, "in-flight booking must keep its one µ-unit floor");

    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.completions.len(), 1);
    assert_eq!(router.work_us(), vec![0], "terminal release must zero the gauge exactly");
}

#[test]
fn round_robin_ignores_load() {
    let model = Arc::new(SlowBackend::new(2));
    let depth = model.entry().config.depth;
    let pool = EngineShardPool::new(
        model,
        PoolConfig { shards: 3, router: RouterPolicy::RoundRobin, ..pool_config(3) },
    );
    let shards: Vec<usize> = (0..6)
        .map(|i| pool.submit(slow_spec(i, depth, "steps:keep=2")).unwrap())
        .collect();
    assert_eq!(shards, vec![0, 1, 2, 0, 1, 2]);
    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.completions.len(), 6);
}

#[test]
fn drain_shutdown_finishes_requests_in_flight() {
    let model = Arc::new(SlowBackend::new(3));
    let depth = model.entry().config.depth;
    let pool = EngineShardPool::new(model.clone(), pool_config(2));
    for i in 0..6 {
        pool.submit(slow_spec(i, depth, "full")).unwrap();
    }
    // immediately drain: every submitted request must still complete
    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.completions.len(), 6);
    assert_eq!(out.stats.completed, 6);
    assert_eq!(out.stats.inflight, 0);
    let mut ids: Vec<u64> = out.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
}

#[test]
fn halt_shutdown_joins_cleanly_with_requests_in_flight() {
    let model = Arc::new(SlowBackend::new(10));
    let depth = model.entry().config.depth;
    let pool = EngineShardPool::new(model.clone(), pool_config(2));
    for i in 0..4 {
        pool.submit(slow_spec(i, depth, "full")).unwrap();
    }
    // halt abandons work but must join without hanging or panicking
    let out = pool.shutdown(false).unwrap();
    assert!(out.completions.len() <= 4);
    assert!(out.stats.completed as usize == out.completions.len());
    // every submitted request is accounted for: completed or aborted
    assert_eq!(out.completions.len() + out.aborted.len(), 4);
    for (_, reason) in &out.aborted {
        assert_eq!(reason, "shard halted");
    }
}

/// Backend whose forward passes always fail (after a generous sleep, so
/// the test's submits land well before the first tick errors out even on
/// a heavily loaded runner).
struct FailingBackend {
    entry: ModelEntry,
}

impl FailingBackend {
    fn new() -> FailingBackend {
        FailingBackend {
            entry: synthetic_entry(&ModelConfig::native_test(), &NativeArch::default()),
        }
    }
}

impl ModelBackend for FailingBackend {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn kind(&self) -> &'static str {
        "failing-stub"
    }

    fn supports(&self, entry_point: &str) -> bool {
        matches!(entry_point, "full" | "full_eps" | "block" | "head")
    }

    fn warmup(&self, _e: &[&str], _b: &[usize]) -> anyhow::Result<()> {
        Ok(())
    }

    fn full(
        &self,
        _bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
        _pallas: bool,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        std::thread::sleep(Duration::from_millis(100));
        anyhow::bail!("injected backend failure")
    }

    fn full_eps(
        &self,
        _bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        std::thread::sleep(Duration::from_millis(100));
        anyhow::bail!("injected backend failure")
    }

    fn block(
        &self,
        _bucket: usize,
        _layer: i32,
        _feat: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        anyhow::bail!("injected backend failure")
    }

    fn head(&self, _b: usize, _f: &[f32], _t: &[f32], _y: &[i32]) -> anyhow::Result<Tensor> {
        anyhow::bail!("injected backend failure")
    }
}

#[test]
fn dead_shard_releases_load_gauge_and_aborts_waiters() {
    let model = Arc::new(FailingBackend::new());
    let depth = model.entry().config.depth;
    let mut pool = EngineShardPool::new(model, pool_config(1));
    let events = pool.take_event_rx().unwrap();
    let router = pool.router();

    // both land before the first (slow) tick fails and kills the shard
    pool.submit(slow_spec(0, depth, "full")).unwrap();
    pool.submit(slow_spec(1, depth, "full")).unwrap();

    // every abandoned request gets an abort notice carrying the error
    // (Admitted/Progress chatter may precede the aborts)
    let mut aborted_ids = Vec::new();
    while aborted_ids.len() < 2 {
        match events.recv_timeout(Duration::from_secs(20)).expect("an abort event") {
            JobEvent::Aborted { id, error } => {
                assert!(error.contains("injected backend failure"), "got: {error}");
                aborted_ids.push(id);
            }
            JobEvent::Completed(c) => panic!("request {} completed on a failing backend", c.id),
            _ => {}
        }
    }
    aborted_ids.sort_unstable();
    assert_eq!(aborted_ids, vec![0, 1]);

    // the gauge was tombstoned before the aborts were emitted, so
    // admission control sees a free pool again (no permanent "queue full")
    // and the dead shard reports as such
    assert_eq!(router.inflight(), 0, "dead shard must not pin the load gauge");
    assert_eq!(router.loads(), vec![usize::MAX], "dead shard must be tombstoned");

    // with every worker dead, submission fails fast instead of hanging
    let err = pool.submit(slow_spec(2, depth, "full")).unwrap_err().to_string();
    assert!(err.contains("all shard workers are gone"), "got: {err}");

    // the backend error resurfaces from shutdown
    let err = pool.shutdown(true).unwrap_err().to_string();
    assert!(err.contains("shard worker error"), "got: {err}");
}

#[test]
fn pool_stats_aggregate_across_shards() {
    let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 0x57A7));
    let depth = model.entry().config.depth;
    let classes = model.entry().config.num_classes;
    let pool = EngineShardPool::new(model.clone(), pool_config(3));
    for spec in workload(depth, classes) {
        pool.submit(spec).unwrap();
    }
    let live = pool.stats();
    // live snapshot sums over shards: nothing lost, nothing double-counted
    // (submits and the stats probe share each shard's FIFO queue, so every
    // request is either completed or inflight by the time a shard replies)
    assert_eq!(live.completed as usize + live.inflight, 8);
    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.stats.completed, 8);
    assert_eq!(out.stats.inflight, 0);
    assert!(out.stats.ticks > 0);
    assert!(out.stats.flops.total() > 0, "native runs must book FLOPs");
}

#[test]
fn sharded_pools_stop_growing_after_peak_concurrency() {
    // Three shard workers drive ONE shared native backend. The workspace
    // pool grows to peak concurrency (one arena per simultaneously
    // ticking shard) and the result-buffer pool to the result shapes
    // concurrently in flight; after a few settling rounds of identical
    // load, both counters must freeze — every further checkout recycles
    // (the multi-thread counterpart of tests/alloc_discipline.rs, which
    // pins the single-engine steady state to zero allocations).
    let model = Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 0x900F));
    for _ in 0..3 {
        run_workload(&model, 3);
    }
    let ws = model.workspaces_created();
    let misses = model.result_pool_misses();
    assert!(ws >= 1, "settling rounds must have materialized a workspace");
    for round in 0..2 {
        run_workload(&model, 3);
        assert_eq!(
            model.workspaces_created(),
            ws,
            "workspace pool grew after settling (round {round})"
        );
        assert_eq!(
            model.result_pool_misses(),
            misses,
            "result-buffer pool missed after settling (round {round})"
        );
    }
}
