//! Integration: checkpointable in-flight requests (DESIGN.md §13).
//! The load-bearing guarantee is that parking a request at a step
//! boundary and resuming it — on the same engine, on a different
//! engine, or through the byte codec — is *bitwise invisible*: the
//! final latent, the verify trace, the step accounting and the booked
//! FLOPs all match an uninterrupted run exactly. On top of that
//! contract: priority preemption parks a running victim without losing
//! it, an idle shard steals mid-flight work from a loaded peer, a dead
//! shard's requests migrate to live peers and complete instead of
//! aborting, and `drain_shard` retires one shard without dropping work.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use speca::config::{ModelConfig, ModelEntry};
use speca::coordinator::policy::Policy;
use speca::coordinator::state::{Completion, RequestCheckpoint, RequestSpec};
use speca::coordinator::{
    Admission, Engine, EngineConfig, EngineShardPool, JobEvent, JobMeta, PoolConfig, Priority,
    RouterPolicy,
};
use speca::runtime::native::{synthetic_entry, NativeArch};
use speca::runtime::{ModelBackend, NativeBackend};
use speca::tensor::Tensor;
use speca::util::rng::Rng;
use speca::workload::parse_policy;

fn native_model() -> Arc<NativeBackend> {
    Arc::new(NativeBackend::seeded(ModelConfig::native_test(), 0xC4EC))
}

fn spec(id: u64, depth: usize, desc: &str) -> RequestSpec {
    RequestSpec {
        id,
        cond: (id % 4) as i32,
        seed: 100 + id,
        policy: parse_policy(desc, depth).unwrap(),
        record_traj: false,
        meta: JobMeta::default(),
    }
}

/// The request run start-to-finish on one engine with no interruption —
/// the reference every park/resume variant must match bitwise.
fn run_uninterrupted(model: &Arc<NativeBackend>, s: RequestSpec) -> Completion {
    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(s);
    let mut done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    done.pop().unwrap()
}

/// Everything observable about a completion except wall-clock latency
/// must match exactly (f32/f64 compared by value, which for identical
/// bit patterns is exact).
fn assert_bitwise(a: &Completion, b: &Completion, what: &str) {
    assert_eq!(a.id, b.id, "{what}: id");
    assert_eq!(a.policy_name, b.policy_name, "{what}: policy");
    assert_eq!(a.latent, b.latent, "{what}: final latent drifted");
    assert_eq!(a.stats.full_steps, b.stats.full_steps, "{what}: full steps");
    assert_eq!(a.stats.spec_steps, b.stats.spec_steps, "{what}: spec steps");
    assert_eq!(a.stats.skip_steps, b.stats.skip_steps, "{what}: skip steps");
    assert_eq!(a.stats.blend_steps, b.stats.blend_steps, "{what}: blend steps");
    assert_eq!(a.stats.elided_steps, b.stats.elided_steps, "{what}: elided steps");
    assert_eq!(a.stats.rejects, b.stats.rejects, "{what}: rejects");
    assert_eq!(a.stats.verify_trace, b.stats.verify_trace, "{what}: verify trace");
    assert_eq!(a.stats.flops.total(), b.stats.flops.total(), "{what}: booked FLOPs");
}

#[test]
fn park_resume_is_bitwise_at_every_step_boundary() {
    let model = native_model();
    let depth = model.entry().config.depth;
    let total = model.entry().config.serve_steps;
    // a strict-threshold SpeCa request (rejections happen, so the verify
    // trace is nontrivial) and a TeaCache request (drift accumulator +
    // refresh embedding must survive the checkpoint)
    for desc in ["speca:N=5,O=2,tau0=0.01,beta=0.05", "teacache:l=0.6"] {
        let reference = run_uninterrupted(&model, spec(0, depth, desc));
        for boundary in 1..total {
            let mut engine = Engine::new(model.clone(), EngineConfig::default());
            engine.submit(spec(0, depth, desc));
            for _ in 0..boundary {
                assert!(engine.tick().unwrap(), "{desc}: engine idle before boundary {boundary}");
            }
            let mut units = engine.park_all();
            assert_eq!(units.len(), 1, "{desc}: boundary {boundary}");
            assert_eq!(engine.parked, 1);
            let Some(Admission::Parked(ckpt)) = units.pop() else {
                panic!("{desc}: boundary {boundary} parked a fresh spec");
            };
            assert_eq!(ckpt.step, boundary, "{desc}: parked off-boundary");
            // resume on a *different* engine over the same shared model:
            // the checkpoint is shard-independent by construction
            let mut peer = Engine::new(model.clone(), EngineConfig::default());
            peer.submit_checkpoint(ckpt);
            let mut done = peer.run_to_completion().unwrap();
            assert_eq!(done.len(), 1);
            assert_eq!(peer.resumed, 1);
            let what = format!("{desc}: resume at boundary {boundary}");
            assert_bitwise(&reference, &done.pop().unwrap(), &what);
        }
    }
}

#[test]
fn checkpoint_byte_codec_round_trips_and_rejects_corruption() {
    let model = native_model();
    let depth = model.entry().config.depth;
    let desc = "speca:N=5,O=2,tau0=0.3,beta=0.05";
    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(spec(3, depth, desc));
    for _ in 0..4 {
        assert!(engine.tick().unwrap());
    }
    let Some(Admission::Parked(ckpt)) = engine.park_all().pop() else {
        panic!("expected one parked checkpoint");
    };
    let policy = ckpt.spec.policy.clone();
    let meta = ckpt.spec.meta.clone();
    let bytes = ckpt.to_bytes();
    // decode → re-encode is byte-identical: the codec is canonical
    let decoded = RequestCheckpoint::from_bytes(&bytes, policy.clone(), meta.clone()).unwrap();
    assert_eq!(decoded.to_bytes(), bytes);
    // resuming the decoded image still matches the uninterrupted run —
    // the byte form loses nothing the schedule can observe
    let reference = run_uninterrupted(&model, spec(3, depth, desc));
    let mut peer = Engine::new(model.clone(), EngineConfig::default());
    peer.submit_checkpoint(Box::new(decoded));
    let done = peer.run_to_completion().unwrap();
    assert_bitwise(&reference, &done[0], "byte-codec resume");
    // truncation and a corrupt header both error instead of panicking
    let cut = &bytes[..bytes.len() - 3];
    assert!(RequestCheckpoint::from_bytes(cut, policy.clone(), meta.clone()).is_err());
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    assert!(RequestCheckpoint::from_bytes(&bad, policy, meta).is_err());
}

/// Park one request after `ticks` engine ticks and return its byte
/// image plus the policy/meta needed to decode it again.
fn parked_blob(
    model: &Arc<NativeBackend>,
    desc: &str,
    ticks: usize,
) -> (Vec<u8>, Policy, JobMeta) {
    let depth = model.entry().config.depth;
    let mut engine = Engine::new(model.clone(), EngineConfig::default());
    engine.submit(spec(9, depth, desc));
    for _ in 0..ticks {
        assert!(engine.tick().unwrap());
    }
    let Some(Admission::Parked(ckpt)) = engine.park_all().pop() else {
        panic!("{desc}: expected one parked checkpoint");
    };
    (ckpt.to_bytes(), ckpt.spec.policy.clone(), ckpt.spec.meta.clone())
}

/// Strip the v3 lookahead appendix — on a static-policy (`lookahead`
/// unset, so cap-1) image parked outside a run that is the two-bucket
/// accepted-prefix histogram block plus a zero run-flag word — and
/// patch the version field: byte-for-byte the layout a v2 writer
/// produced.
fn downgrade_to_v2(v3: &[u8]) -> Vec<u8> {
    let n = v3.len();
    assert_eq!(&v3[n - 4..], &[0u8; 4], "expected an image parked outside a run");
    assert_eq!(&v3[n - 28..n - 20], &2u64.to_le_bytes(), "expected a cap-1 histogram");
    let mut v2 = v3[..n - 28].to_vec();
    v2[4..8].copy_from_slice(&2u32.to_le_bytes());
    v2
}

/// Further strip the v2 controller appendix (a single zero flag word on
/// static-policy images) and patch the version field — byte-for-byte
/// the layout a v1 writer produced.
fn downgrade_to_v1(v2: &[u8]) -> Vec<u8> {
    assert_eq!(&v2[v2.len() - 4..], &[0u8; 4], "expected a no-controller image");
    let mut v1 = v2[..v2.len() - 4].to_vec();
    v1[4..8].copy_from_slice(&1u32.to_le_bytes());
    v1
}

#[test]
fn spck_v1_images_still_decode_and_resume_bitwise() {
    let model = native_model();
    let depth = model.entry().config.depth;
    let desc = "speca:N=5,O=2,tau0=0.3,beta=0.05";
    let (v3, policy, meta) = parked_blob(&model, desc, 4);
    let v1 = downgrade_to_v1(&downgrade_to_v2(&v3));
    let decoded = RequestCheckpoint::from_bytes(&v1, policy, meta).unwrap();
    assert!(decoded.ctl.is_none(), "v1 images carry no controller state");
    assert!(decoded.look.is_empty(), "v1 images carry no in-flight run");
    // re-encoding upgrades to v3: the zero controller and run flags come
    // back verbatim, and the accepted-prefix histogram — the one record
    // a v1 writer never kept — returns zeroed
    let mut expect = v3.clone();
    expect[v3.len() - 20..v3.len() - 4].fill(0);
    assert_eq!(decoded.to_bytes(), expect);
    let reference = run_uninterrupted(&model, spec(9, depth, desc));
    let mut peer = Engine::new(model.clone(), EngineConfig::default());
    peer.submit_checkpoint(Box::new(decoded));
    let done = peer.run_to_completion().unwrap();
    assert_bitwise(&reference, &done[0], "v1 image resume");
}

/// Structured fuzz over the SPCK codec: deterministic xorshift-driven
/// truncation, single-bit flips and length-prefix blasts over v1, v2
/// and v3 images (with and without controller state, and one parked
/// mid-speculation so the in-flight run appendix is exercised). The
/// invariants: decode never panics; an `Ok` decode of a v3 image
/// re-encodes bitwise identically (the codec is canonical); an `Ok`
/// decode of a v1/v2 image upgrades to a stable v3 image; every `Err`
/// carries a message.
#[test]
fn spck_codec_structured_fuzz_never_panics_and_stays_canonical() {
    fn check(bytes: &[u8], policy: &Policy, meta: &JobMeta) -> bool {
        match RequestCheckpoint::from_bytes(bytes, policy.clone(), meta.clone()) {
            Ok(ck) => {
                let re = ck.to_bytes();
                if bytes.len() >= 8 && bytes[4..8] == 3u32.to_le_bytes() {
                    assert_eq!(re, bytes, "v3 decode∘encode must be the identity");
                } else {
                    let again = RequestCheckpoint::from_bytes(&re, policy.clone(), meta.clone())
                        .expect("re-encoded image must decode");
                    assert_eq!(again.to_bytes(), re, "v1/v2→v3 upgrade must be stable");
                }
                true
            }
            Err(e) => {
                assert!(!e.is_empty(), "errors must carry a message");
                false
            }
        }
    }

    let model = native_model();
    let mut blobs = Vec::new();
    for (desc, ticks) in [
        ("speca:N=5,O=2,tau0=0.3,beta=0.05", 4),
        ("speca:N=4,O=1,tau0=0.3,beta=0.05,adaptive=0.5", 5),
        ("speca:N=12,O=2,tau0=0.3,beta=0.05,lookahead=4", 4),
        ("teacache:l=0.6", 3),
    ] {
        blobs.push(parked_blob(&model, desc, ticks));
    }
    let (v3, policy, meta) = blobs[0].clone();
    let v2 = downgrade_to_v2(&v3);
    blobs.push((downgrade_to_v1(&v2), policy.clone(), meta.clone()));
    blobs.push((v2, policy, meta));

    let mut rng = Rng::new(0x5943_F00D);
    for (bytes, policy, meta) in &blobs {
        assert!(check(bytes, policy, meta), "pristine image must decode");
        for _ in 0..300 {
            let mut m = bytes.clone();
            match rng.below(3) {
                // truncation at a random byte
                0 => m.truncate(rng.below(bytes.len() + 1)),
                // single-bit flip
                1 => {
                    let i = rng.below(m.len());
                    m[i] ^= 1 << rng.below(8);
                }
                // length-prefix corruption: blast an aligned word with a
                // value far past the end of the buffer
                _ => {
                    let i = rng.below(m.len() / 4) * 4;
                    let v = 0xFFFF_0000u32 | (rng.next_u64() as u32 & 0xFFFF);
                    m[i..i + 4].copy_from_slice(&v.to_le_bytes());
                }
            }
            check(&m, policy, meta);
        }
    }
}

#[test]
fn preemption_frees_the_slot_without_losing_the_victim() {
    let model = native_model();
    let depth = model.entry().config.depth;
    let mut low = spec(0, depth, "speca:N=5,O=2,tau0=0.01,beta=0.05");
    low.meta.priority = Priority::Low;
    low.meta.preemptible = true;
    let reference = run_uninterrupted(&model, low.clone());

    let cfg = EngineConfig { max_inflight: 1, ..EngineConfig::default() };
    let mut engine = Engine::new(model.clone(), cfg);
    engine.submit(low);
    for _ in 0..3 {
        assert!(engine.tick().unwrap());
    }
    let mut high = spec(1, depth, "full");
    high.meta.priority = Priority::High;
    engine.submit(high);
    let mut done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    assert_eq!(engine.parked, 1, "the low job must be parked exactly once");
    assert_eq!(engine.resumed, 1, "... and resumed after the high job finishes");
    // the high job overtook the victim's head start (slot freed mid-flight)
    assert_eq!(done[0].id, 1, "high-priority job must finish first");
    // and the victim's outcome is bitwise-unchanged by the round trip
    done.sort_by_key(|c| c.id);
    assert_bitwise(&reference, &done[0], "preempted victim");
}

#[test]
fn non_preemptible_jobs_are_never_parked() {
    let model = native_model();
    let depth = model.entry().config.depth;
    let cfg = EngineConfig { max_inflight: 1, ..EngineConfig::default() };
    let mut engine = Engine::new(model.clone(), cfg);
    let mut low = spec(0, depth, "full");
    low.meta.priority = Priority::Low; // preemptible stays default false
    engine.submit(low);
    for _ in 0..3 {
        assert!(engine.tick().unwrap());
    }
    let mut high = spec(1, depth, "full");
    high.meta.priority = Priority::High;
    engine.submit(high);
    let done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    assert_eq!(engine.parked, 0, "non-preemptible jobs must never be parked");
    assert_eq!(done[0].id, 0, "the high job waits for the running slot-holder");
}

// ---------------------------------------------------------------------------
// Pool-level behaviour over slow / fault-injecting stub backends
// ---------------------------------------------------------------------------

/// Zero-math backend whose forward passes sleep, making shard residency
/// long and measurable so steal/drain/migration interleavings are
/// deterministic. `armed` injects exactly one forward-pass failure
/// (whichever shard dispatches first), for the crash-migration test.
struct SlowBackend {
    entry: ModelEntry,
    delay: Duration,
    armed: AtomicBool,
}

impl SlowBackend {
    fn new(delay_ms: u64) -> SlowBackend {
        SlowBackend {
            entry: synthetic_entry(&ModelConfig::native_test(), &NativeArch::default()),
            delay: Duration::from_millis(delay_ms),
            armed: AtomicBool::new(false),
        }
    }

    fn poisoned(delay_ms: u64) -> SlowBackend {
        let b = SlowBackend::new(delay_ms);
        b.armed.store(true, Ordering::SeqCst);
        b
    }

    fn forward_gate(&self) -> anyhow::Result<()> {
        thread::sleep(self.delay);
        if self.armed.swap(false, Ordering::SeqCst) {
            anyhow::bail!("injected backend failure");
        }
        Ok(())
    }
}

impl ModelBackend for SlowBackend {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn kind(&self) -> &'static str {
        "slow-stub"
    }

    fn supports(&self, entry_point: &str) -> bool {
        matches!(entry_point, "full" | "full_eps" | "block" | "head")
    }

    fn warmup(&self, _e: &[&str], _b: &[usize]) -> anyhow::Result<()> {
        Ok(())
    }

    fn full(
        &self,
        bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
        _pallas: bool,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        self.forward_gate()?;
        let c = &self.entry.config;
        Ok((
            Tensor::zeros(vec![bucket, c.latent_dim]),
            Tensor::zeros(vec![c.depth + 1, bucket, c.tokens, c.dim]),
        ))
    }

    fn full_eps(
        &self,
        bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        self.forward_gate()?;
        Ok(Tensor::zeros(vec![bucket, self.entry.config.latent_dim]))
    }

    fn block(
        &self,
        bucket: usize,
        _layer: i32,
        _feat: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        let c = &self.entry.config;
        Ok(Tensor::zeros(vec![bucket, c.tokens, c.dim]))
    }

    fn head(&self, bucket: usize, _f: &[f32], _t: &[f32], _y: &[i32]) -> anyhow::Result<Tensor> {
        Ok(Tensor::zeros(vec![bucket, self.entry.config.latent_dim]))
    }
}

fn slow_spec(id: u64, depth: usize, desc: &str) -> RequestSpec {
    RequestSpec {
        id,
        cond: 0,
        seed: id,
        policy: parse_policy(desc, depth).unwrap(),
        record_traj: false,
        meta: JobMeta::default(),
    }
}

fn pool_config(shards: usize, steal: bool) -> PoolConfig {
    PoolConfig { shards, router: RouterPolicy::LeastLoaded, engine: EngineConfig::default(), steal }
}

#[test]
fn idle_shard_steals_mid_request_from_the_loaded_peer() {
    let model = Arc::new(SlowBackend::new(15));
    let depth = model.entry().config.depth;
    let pool = EngineShardPool::new(model, pool_config(2, true));

    // a quick job with a heavy cost hint parks shard 0's work gauge
    // high, steering the slow preemptible backlog entirely to shard 1 —
    // a deliberately skewed placement the thief must then repair
    let mut quick = slow_spec(0, depth, "steps:keep=2");
    quick.meta.cost_hint = 60.0;
    assert_eq!(pool.submit(quick).unwrap(), 0);
    for i in 1..=4 {
        let mut s = slow_spec(i, depth, "full");
        s.meta.cost_hint = 5.0;
        s.meta.preemptible = true;
        assert_eq!(pool.submit(s).unwrap(), 1, "hinted routing must skew to shard 1");
    }

    // shard 0 finishes its 2 kept steps in ~30 ms and goes idle while
    // shard 1 still holds ~180 ms of batched work — wait for the steal
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let s = pool.stats();
        if s.stolen >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "idle shard never stole: {s:?}");
        thread::sleep(Duration::from_millis(5));
    }

    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.completions.len(), 5, "stolen work must still complete");
    assert!(out.stats.stolen >= 1, "steal counter lost: {:?}", out.stats);
    assert!(out.stats.parked >= 1, "the victim parks a mid-flight unit: {:?}", out.stats);
    assert!(out.stats.resumed >= 1, "the thief resumes it: {:?}", out.stats);
}

#[test]
fn dead_shards_jobs_migrate_and_complete_instead_of_aborting() {
    let model = Arc::new(SlowBackend::poisoned(30));
    let depth = model.entry().config.depth;
    let mut pool = EngineShardPool::new(model, pool_config(2, false));
    let events = pool.take_event_rx().unwrap();
    let router = pool.router();

    // 2 requests per shard, all routed before the first (slow) tick can
    // trip the injected failure on whichever shard dispatches first
    for i in 0..4 {
        pool.submit(slow_spec(i, depth, "full")).unwrap();
    }

    // every request completes — the dead shard's jobs resume on the
    // peer; any Aborted event is a containment failure
    let mut completed = Vec::new();
    while completed.len() < 4 {
        match events.recv_timeout(Duration::from_secs(30)).expect("a completion event") {
            JobEvent::Completed(c) => completed.push(c.id),
            JobEvent::Aborted { id, error } => panic!("request {id} aborted: {error}"),
            _ => {}
        }
    }
    completed.sort_unstable();
    assert_eq!(completed, vec![0, 1, 2, 3]);

    // the survivor accounted the handoff and the dead shard is tombstoned
    let s = router.stats();
    // (≥, not ==: a submit racing the failing tick migrates as a fresh
    // unit, which resumes without counting as a parked checkpoint)
    assert!(s.migrated >= 2, "peer must report the migrated units: {s:?}");
    assert!(s.resumed >= 1, "migrated checkpoints resume on the peer: {s:?}");
    assert_eq!(router.loads().iter().filter(|l| **l == usize::MAX).count(), 1);

    // the injected error still resurfaces from shutdown — migration
    // saves the requests, not the broken shard
    let err = pool.shutdown(true).unwrap_err().to_string();
    assert!(err.contains("injected backend failure"), "got: {err}");
}

#[test]
fn drain_shard_migrates_in_flight_work_to_live_peers() {
    let model = Arc::new(SlowBackend::new(10));
    let depth = model.entry().config.depth;
    let pool = EngineShardPool::new(model, pool_config(2, false));
    let router = pool.router();
    for i in 0..6 {
        pool.submit(slow_spec(i, depth, "full")).unwrap(); // 3 per shard
    }
    // let shard 0 admit its requests and advance them mid-flight, so the
    // drain migrates *parked checkpoints*, not just untouched queue units
    thread::sleep(Duration::from_millis(40));
    assert!(pool.drain_shard(0), "drain message must reach a live worker");

    // the drained shard evacuates and exits; its gauge tombstones
    let deadline = Instant::now() + Duration::from_secs(20);
    while router.loads()[0] != usize::MAX {
        assert!(Instant::now() < deadline, "drained shard never exited");
        thread::sleep(Duration::from_millis(2));
    }
    // new work routes around the drained shard from then on
    assert_eq!(router.submit(slow_spec(6, depth, "steps:keep=2")).unwrap(), 1);

    let out = pool.shutdown(true).unwrap();
    assert_eq!(out.completions.len(), 7, "no request may be lost to the drain");
    assert!(out.stats.parked >= 1, "drain parks mid-flight work: {:?}", out.stats);
    assert!(out.stats.migrated >= 1, "the peer reports received units: {:?}", out.stats);
    assert!(out.stats.resumed >= 1, "migrated checkpoints resume: {:?}", out.stats);
}
