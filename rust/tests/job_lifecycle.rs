//! Integration: the job-lifecycle API (`coordinator::job`). The
//! load-bearing guarantees:
//!
//! * a cancelled job frees its shard slot **mid-flight** — queued work
//!   behind it runs, and the slot is reusable for later submissions;
//! * a deadline that cannot be met sheds the job with a structured
//!   `Rejected` instead of queueing doomed work;
//! * the accounting identity `completed + rejected + cancelled +
//!   aborted == submitted` holds on every shutdown path — no job is
//!   ever silently lost;
//! * shard queues admit strictly by priority (FIFO within a class).
//!
//! Timing-sensitive tests run over a slow deterministic stub backend so
//! request lifetimes are long and measurable.

use std::sync::Arc;
use std::time::Duration;

use speca::config::{ModelConfig, ModelEntry};
use speca::coordinator::job::{JobManager, JobStatus, RejectReason, SubmitOptions};
use speca::coordinator::state::RequestSpec;
use speca::coordinator::{
    Engine, EngineConfig, JobMeta, PoolConfig, Priority, RouterPolicy, TerminationCause,
};
use speca::runtime::native::{synthetic_entry, NativeArch};
use speca::runtime::{ModelBackend, NativeBackend};
use speca::tensor::Tensor;
use speca::workload::parse_policy;

/// Zero-math backend whose full pass sleeps: makes request lifetimes
/// long enough that cancellation/deadline interleavings are
/// deterministic.
struct SlowBackend {
    entry: ModelEntry,
    delay: Duration,
}

impl SlowBackend {
    fn new(delay_ms: u64) -> SlowBackend {
        SlowBackend {
            entry: synthetic_entry(&ModelConfig::native_test(), &NativeArch::default()),
            delay: Duration::from_millis(delay_ms),
        }
    }
}

impl ModelBackend for SlowBackend {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn kind(&self) -> &'static str {
        "slow-stub"
    }

    fn supports(&self, entry_point: &str) -> bool {
        matches!(entry_point, "full" | "full_eps" | "block" | "head")
    }

    fn warmup(&self, _e: &[&str], _b: &[usize]) -> anyhow::Result<()> {
        Ok(())
    }

    fn full(
        &self,
        bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
        _pallas: bool,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        std::thread::sleep(self.delay);
        let c = &self.entry.config;
        Ok((
            Tensor::zeros(vec![bucket, c.latent_dim]),
            Tensor::zeros(vec![c.depth + 1, bucket, c.tokens, c.dim]),
        ))
    }

    fn full_eps(
        &self,
        bucket: usize,
        _x: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        std::thread::sleep(self.delay);
        Ok(Tensor::zeros(vec![bucket, self.entry.config.latent_dim]))
    }

    fn block(
        &self,
        bucket: usize,
        _layer: i32,
        _feat: &[f32],
        _t: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<Tensor> {
        let c = &self.entry.config;
        Ok(Tensor::zeros(vec![bucket, c.tokens, c.dim]))
    }

    fn head(&self, bucket: usize, _f: &[f32], _t: &[f32], _y: &[i32]) -> anyhow::Result<Tensor> {
        Ok(Tensor::zeros(vec![bucket, self.entry.config.latent_dim]))
    }
}

fn slow_manager(delay_ms: u64, max_inflight: usize, max_queue: usize) -> JobManager {
    JobManager::new(
        Arc::new(SlowBackend::new(delay_ms)),
        PoolConfig {
            shards: 1,
            router: RouterPolicy::LeastLoaded,
            engine: EngineConfig { max_inflight, ..EngineConfig::default() },
            steal: false,
        },
        max_queue,
    )
}

fn depth() -> usize {
    ModelConfig::native_test().depth
}

const WAIT: Duration = Duration::from_secs(60);

#[test]
fn cancelled_job_frees_shard_capacity_mid_flight() {
    // one shard, one engine slot: the blocker owns all capacity
    let mgr = slow_manager(20, 1, 64);
    let policy = parse_policy("full", depth()).unwrap();

    let a = mgr.submit(0, Some(1), policy.clone(), SubmitOptions::default());
    let b = mgr.submit(0, Some(2), policy.clone(), SubmitOptions::default());

    // let A reach the active set (12 full steps × 20 ms ≫ this poll loop)
    for _ in 0..1000 {
        if matches!(a.poll(), JobStatus::Running { .. }) {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let sa0 = a.poll();
    assert!(matches!(sa0, JobStatus::Running { .. }), "blocker never started: {sa0:?}");

    // cancel mid-flight: the engine drops A at the next step boundary
    a.cancel();
    let sa = a.wait_timeout(WAIT);
    assert!(matches!(sa, JobStatus::Cancelled), "cancelled job must end Cancelled, got {sa:?}");

    // the freed slot lets B (queued behind A) run to completion
    let sb = b.wait_timeout(WAIT);
    assert!(matches!(sb, JobStatus::Completed(_)), "queued job must inherit the slot, got {sb:?}");

    // and the slot is reusable for a job submitted after the cancel
    let c = mgr.submit(0, Some(3), policy, SubmitOptions::default());
    let sc = c.wait_timeout(WAIT);
    assert!(matches!(sc, JobStatus::Completed(_)), "slot not reusable after cancel: {sc:?}");

    let out = mgr.shutdown(true).unwrap();
    assert_eq!(out.counts.submitted, 3);
    assert_eq!(out.counts.completed, 2);
    assert_eq!(out.counts.cancelled, 1);
    assert_eq!(out.counts.rejected, 0);
    assert_eq!(out.counts.aborted, 0);
    assert_eq!(
        out.counts.terminal(),
        out.counts.submitted,
        "completed + rejected + cancelled + aborted must equal submitted"
    );
    assert_eq!(mgr.inflight(), 0, "every slot released (cancel freed its load accounting)");
    assert_eq!(mgr.live(), 0, "no job left in a non-terminal state");
}

#[test]
fn per_policy_ewma_feeds_submission_cost_hints() {
    // Completions build a per-policy-family service-time EWMA; later
    // submissions of that family carry it as their routing cost hint
    // (ShardRouter weighs expected remaining work with it).
    let mgr = slow_manager(2, 4, 64);
    let full = parse_policy("full", depth()).unwrap();
    let cheap = parse_policy("steps:keep=2", depth()).unwrap();
    assert!(mgr.est_for_policy("full").is_none(), "no estimate before any completion");

    // run sequentially so each family's latency reflects its own work
    // (12 slow full passes vs 2 kept steps + 10 instant elides)
    let a = mgr.submit(0, Some(1), full.clone(), SubmitOptions::default());
    assert!(matches!(a.wait_timeout(WAIT), JobStatus::Completed(_)));
    let b = mgr.submit(0, Some(2), cheap, SubmitOptions::default());
    assert!(matches!(b.wait_timeout(WAIT), JobStatus::Completed(_)));

    let est_full = mgr.est_for_policy("full").expect("full family has completions");
    let est_cheap =
        mgr.est_for_policy("step-reduction").expect("step-reduction family has completions");
    assert!(est_full > 0.0 && est_cheap > 0.0);
    // 12 slow full steps vs 2: the family estimates must reflect the skew
    assert!(
        est_full > est_cheap,
        "full ({est_full:.2} ms) must estimate costlier than step-reduction ({est_cheap:.2} ms)"
    );
    assert!(mgr.est_for_policy("speca").is_none(), "families without completions stay unknown");

    mgr.shutdown(true).unwrap();
}

#[test]
fn expired_deadline_sheds_queued_work_with_structured_rejection() {
    let mgr = slow_manager(20, 1, 64);
    let policy = parse_policy("full", depth()).unwrap();

    // the blocker occupies the only slot for ~240 ms
    let blocker = mgr.submit(0, Some(1), policy.clone(), SubmitOptions::default());
    // 1 ms deadline: expires while queued behind the blocker; the engine
    // must reject it at a step boundary instead of ever admitting it
    let doomed = mgr.submit(
        0,
        Some(2),
        policy,
        SubmitOptions::new().deadline_ms(1),
    );

    let sd = doomed.wait_timeout(WAIT);
    assert!(
        matches!(sd, JobStatus::Rejected { reason: RejectReason::DeadlineExpired }),
        "queued job past its deadline must be rejected, got {sd:?}"
    );
    let sb = blocker.wait_timeout(WAIT);
    assert!(matches!(sb, JobStatus::Completed(_)), "{sb:?}");

    let out = mgr.shutdown(true).unwrap();
    assert_eq!(out.counts.submitted, 2);
    assert_eq!(out.counts.completed, 1);
    assert_eq!(out.counts.rejected, 1);
    assert_eq!(out.counts.terminal(), out.counts.submitted);
    assert_eq!(mgr.inflight(), 0, "a shed job must never consume shard capacity");
}

#[test]
fn admission_rejects_when_queue_is_full() {
    // max_queue = 1: the blocker fills the whole admission budget
    let mgr = slow_manager(20, 1, 1);
    let policy = parse_policy("full", depth()).unwrap();

    let blocker = mgr.submit(0, Some(1), policy.clone(), SubmitOptions::default());
    let extra = mgr.submit(0, Some(2), policy, SubmitOptions::default());
    // rejected synchronously at submit — terminal before any wait
    let se = extra.poll();
    assert!(
        matches!(se, JobStatus::Rejected { reason: RejectReason::QueueFull }),
        "over-cap submit must reject immediately, got {se:?}"
    );
    // the verdict lives on the handle (shed jobs never enter the
    // table), and wait must fall back to it instead of blocking
    let se = extra.wait_timeout(Duration::from_secs(5));
    assert!(
        matches!(se, JobStatus::Rejected { reason: RejectReason::QueueFull }),
        "wait on a shed job must return its rejection, got {se:?}"
    );

    let sb = blocker.wait_timeout(WAIT);
    assert!(matches!(sb, JobStatus::Completed(_)), "{sb:?}");
    let out = mgr.shutdown(true).unwrap();
    assert_eq!(out.counts.submitted, 2);
    assert_eq!(out.counts.completed, 1);
    assert_eq!(out.counts.rejected, 1);
    assert_eq!(out.counts.terminal(), out.counts.submitted);
}

#[test]
fn halt_accounts_for_every_job() {
    // halt abandons in-flight work: completed + aborted must still
    // reconcile with submitted (nothing silently lost)
    let mgr = slow_manager(10, 2, 64);
    let policy = parse_policy("full", depth()).unwrap();
    let handles: Vec<_> =
        (0..4).map(|i| mgr.submit(0, Some(i), policy.clone(), SubmitOptions::default())).collect();
    let out = mgr.shutdown(false).unwrap();
    assert_eq!(out.counts.submitted, 4);
    assert_eq!(out.counts.terminal(), 4, "halt must terminalize every job: {:?}", out.counts);
    assert!(out.counts.aborted > 0, "halting with work in flight must abort something");
    // every handle observes a terminal state without blocking
    for h in &handles {
        assert!(h.poll().is_terminal(), "job {} not terminal after halt", h.id());
    }
}

#[test]
fn priority_orders_admission_within_a_shard() {
    // engine-level check over the real native backend: with one slot,
    // completion order == admission order, which must follow priority
    // classes (high before normal before low; FIFO within a class)
    let model = NativeBackend::seeded(ModelConfig::native_test(), 0x5EED);
    let mut engine =
        Engine::from_ref(&model, EngineConfig { max_inflight: 1, ..EngineConfig::default() });
    let depth = model.entry().config.depth;
    let policy = parse_policy("steps:keep=2", depth).unwrap();
    for (id, priority) in
        [(0u64, Priority::Normal), (1, Priority::Low), (2, Priority::High), (3, Priority::Normal)]
    {
        engine.submit(RequestSpec {
            id,
            cond: 0,
            seed: id,
            policy: policy.clone(),
            record_traj: false,
            meta: JobMeta { priority, ..JobMeta::default() },
        });
    }
    let done = engine.run_to_completion().unwrap();
    let order: Vec<u64> = done.iter().map(|c| c.id).collect();
    // all four are queued before the first tick, so admission (and with
    // one slot, completion) order is: high first, then the normal class
    // FIFO, low last
    assert_eq!(order, vec![2, 0, 3, 1]);
}

#[test]
fn cancel_of_a_queued_job_terminates_before_admission() {
    let model = NativeBackend::seeded(ModelConfig::native_test(), 0x5EED);
    let mut engine =
        Engine::from_ref(&model, EngineConfig { max_inflight: 1, ..EngineConfig::default() });
    let depth = model.entry().config.depth;
    let policy = parse_policy("full", depth).unwrap();
    let meta = JobMeta::default();
    let token = meta.cancel.clone();
    engine.submit(RequestSpec {
        id: 0,
        cond: 0,
        seed: 0,
        policy: policy.clone(),
        record_traj: false,
        meta: JobMeta::default(),
    });
    engine.submit(RequestSpec { id: 1, cond: 0, seed: 1, policy, record_traj: false, meta });
    // fire the queued job's token before it is ever admitted
    token.cancel();
    let done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 1, "only the uncancelled job completes");
    assert_eq!(done[0].id, 0);
    let terms = engine.drain_terminations();
    assert_eq!(terms.len(), 1);
    assert_eq!(terms[0].id, 1);
    assert_eq!(terms[0].cause, TerminationCause::Cancelled);
}
