"""AOT pipeline: tensor container format, HLO text emission, and (when
`make artifacts` has run) manifest completeness."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def read_tensors(path):
    """Independent decoder for the SPCA container (mirrors rust/src/weights)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"SPCA"
        version, n = struct.unpack("<II", f.read(8))
        assert version == 1
        for _ in range(n):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            dtype, ndim = struct.unpack("<BB", f.read(2))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            dt = np.float32 if dtype == 0 else np.int32
            out[name] = np.frombuffer(raw, dt).reshape(shape)
    return out


def test_tensor_container_roundtrip(tmp_path):
    path = tmp_path / "t.bin"
    a = np.arange(6, dtype=np.float32).reshape(2, 3)
    b = np.asarray([7, -1], np.int32)
    aot.write_tensors(str(path), [("a", a), ("b", b)])
    back = read_tensors(path)
    np.testing.assert_array_equal(back["a"], a)
    np.testing.assert_array_equal(back["b"], b)
    assert back["b"].dtype == np.int32


def test_hlo_text_emission(tmp_path):
    path = tmp_path / "f.hlo.txt"
    n = aot.lower_to_file(
        lambda x: (x * 2.0,), [aot.spec([2, 2])], str(path)
    )
    text = path.read_text()
    assert n == len(text)
    assert "HloModule" in text
    # text (not proto) is the interchange contract
    assert text.lstrip().startswith("HloModule")


def test_config_hash_stable():
    from compile.configs import DIT_SIM
    assert aot.config_hash(DIT_SIM) == aot.config_hash(DIT_SIM)


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_complete():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == aot.MANIFEST_VERSION
    for name, entry in m["models"].items():
        cfg = entry["config"]
        for key in ("dim", "depth", "tokens", "latent_dim", "serve_steps", "buckets"):
            assert key in cfg, (name, key)
        assert len(entry["schedule"]["t_model"]) == cfg["serve_steps"]
        for ep in ("full", "block", "head"):
            for b in cfg["buckets"]:
                rel = entry["artifacts"][ep][str(b)]
                assert os.path.exists(os.path.join(ARTIFACTS, rel)), rel
        for f in (entry["weights"], entry["goldens"]):
            assert os.path.exists(os.path.join(ARTIFACTS, f))
        # verification cost ratio gamma ≈ 1/depth (paper §3.5)
        gamma = entry["flops"]["block"]["1"] / entry["flops"]["full_step"]["1"]
        assert gamma < 1.5 / cfg["depth"]
    cls = m["classifier"]
    assert os.path.exists(os.path.join(ARTIFACTS, cls["weights"]))


@needs_artifacts
def test_weights_match_param_spec():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        m = json.load(f)
    for name, entry in m["models"].items():
        tensors = read_tensors(os.path.join(ARTIFACTS, entry["weights"]))
        for spec in entry["params"]:
            t = tensors[spec["name"]]
            assert list(t.shape) == spec["shape"], (name, spec["name"])


@needs_artifacts
def test_goldens_consistent_with_weights():
    """Replaying the golden trace's first step in python from the stored
    weights must reproduce the stored eps (guards against stale caches)."""
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        m = json.load(f)
    from compile.configs import CONFIGS
    for name, entry in m["models"].items():
        cfg = CONFIGS[name]
        tensors = read_tensors(os.path.join(ARTIFACTS, entry["weights"]))
        params = {n: jnp.asarray(tensors[n]) for n in M.PARAM_NAMES}
        g = read_tensors(os.path.join(ARTIFACTS, entry["goldens"]))
        t0 = jnp.asarray([entry["schedule"]["t_model"][0]], jnp.float32)
        y = jnp.asarray(g["y"], jnp.int32)
        eps, bounds = M.full_fwd(params, jnp.asarray(g["x_T"])[None], t0, y, cfg)
        np.testing.assert_allclose(
            np.asarray(eps[0]), g["eps_all"][0], atol=1e-4, err_msg=name
        )
        np.testing.assert_allclose(
            np.asarray(bounds[:, 0]), g["boundaries0"], atol=1e-4, err_msg=name
        )
