"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes and parameters with hypothesis (the CORE correctness
signal for the compute layer)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ddim, ref, taylor, verify

SET = dict(max_examples=12, deadline=None)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t_pow=st.integers(3, 7),        # T in {8..128}
    dh=st.sampled_from([8, 16, 24, 32]),
    blk=st.sampled_from([8, 16, 32]),
)
def test_mha_matches_ref(b, h, t_pow, dh, blk):
    t = 1 << t_pow
    q = rand(1, (b, h, t, dh))
    k = rand(2, (b, h, t, dh))
    v = rand(3, (b, h, t, dh))
    out = attention.mha(q, k, v, blk_q=blk, blk_k=blk)
    expect = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5)


def test_mha_softmax_rows_convex():
    # attention output of constant V must be that constant (softmax sums to 1)
    b, h, t, dh = 1, 2, 16, 8
    q = rand(4, (b, h, t, dh))
    k = rand(5, (b, h, t, dh))
    v = jnp.ones((b, h, t, dh), jnp.float32) * 3.25
    out = attention.mha(q, k, v)
    np.testing.assert_allclose(np.asarray(out), 3.25, atol=1e-5)


def test_mha_vmem_estimate_positive():
    assert attention.vmem_bytes(32, 32, 32) == 4 * (32 * 32 + 2 * 32 * 32 + 32 * 32 + 64)
    u = attention.mxu_utilization_estimate(64, 32, 32, 32)
    assert 0.0 < u <= 1.0


# ---------------------------------------------------------------------------
# TaylorSeer kernels
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    m1=st.integers(1, 5),
    f=st.sampled_from([64, 192, 4096, 6144]),
    k=st.floats(0.5, 9.0),
    n=st.floats(1.0, 10.0),
)
def test_taylor_predict_matches_ref(m1, f, k, n):
    fac = rand(11, (m1, f))
    out = taylor.taylor_predict(fac, k, n)
    expect = ref.taylor_predict_ref(fac, k, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)


@settings(**SET)
@given(m1=st.integers(1, 5), f=st.sampled_from([32, 1024, 6144]))
def test_taylor_update_matches_ref(m1, f):
    fac = rand(12, (m1, f))
    feat = rand(13, (f,))
    out = taylor.taylor_update(fac, feat)
    expect = ref.taylor_update_ref(fac, feat)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=0, rtol=0)


def test_taylor_linear_exactness_and_order_gain():
    """Paper Eq. 2 is exact on linear feature trajectories; on curved ones
    higher orders strictly reduce the extrapolation error (the Table-7
    ordering reuse > AB > Taylor)."""
    n_interval = 4.0
    ts = np.arange(4) * n_interval
    # linear: exact for any k
    fac = jnp.zeros((2, 1), jnp.float32)
    for t in ts:
        fac = taylor.taylor_update(fac, jnp.asarray([2.0 - 3.0 * t], jnp.float32))
    for k in [1.0, 2.0, 5.0]:
        expect = 2.0 - 3.0 * (ts[-1] + k)
        assert abs(float(taylor.taylor_predict(fac, k, n_interval)[0]) - expect) < 1e-3
    # quadratic: order-2 beats order-1 beats order-0
    f = lambda t: 1.0 + 2.0 * t + 0.5 * t * t
    fac = jnp.zeros((3, 1), jnp.float32)
    for t in ts:
        fac = taylor.taylor_update(fac, jnp.asarray([f(t)], jnp.float32))
    truth = f(ts[-1] + 3.0)
    errs = []
    for order in [0, 1, 2]:
        pred = taylor.taylor_predict(fac[: order + 1], 3.0, n_interval)
        errs.append(abs(float(pred[0]) - truth))
    assert errs[2] < errs[1] < errs[0]


def test_pick_blk_divides():
    for f in [1, 7, 64, 6144, 8192, 12000]:
        blk = taylor.pick_blk(f, 4096)
        assert 1 <= blk <= min(f, 4096)
        assert f % blk == 0


# ---------------------------------------------------------------------------
# Verification stats
# ---------------------------------------------------------------------------

@settings(**SET)
@given(f=st.sampled_from([16, 512, 6144]), scale=st.floats(0.1, 10.0))
def test_verify_stats_all_metrics(f, scale):
    a = rand(21, (f,)) * scale
    b = rand(22, (f,))
    np.testing.assert_allclose(float(verify.rel_l2(a, b)), float(ref.rel_l2_ref(a, b)), rtol=1e-5)
    np.testing.assert_allclose(float(verify.rel_l1(a, b)), float(ref.rel_l1_ref(a, b)), rtol=1e-5)
    np.testing.assert_allclose(float(verify.rel_linf(a, b)), float(ref.rel_linf_ref(a, b)), rtol=1e-5)
    np.testing.assert_allclose(
        float(verify.cosine_err(a, b)), float(ref.cosine_err_ref(a, b)), atol=1e-6
    )


def test_verify_stats_single_pass_fields():
    a = jnp.asarray([1.0, 2.0], jnp.float32)
    b = jnp.asarray([0.0, 2.0], jnp.float32)
    s = np.asarray(verify.verify_stats(a, b))
    assert s[0] == pytest.approx(1.0)     # Σd²
    assert s[1] == pytest.approx(4.0)     # Σa²
    assert s[2] == pytest.approx(1.0)     # Σ|d|
    assert s[3] == pytest.approx(2.0)     # Σ|a|
    assert s[4] == pytest.approx(1.0)     # max|d|
    assert s[5] == pytest.approx(2.0)     # max|a|
    assert s[6] == pytest.approx(4.0)     # Σp·a
    assert s[7] == pytest.approx(5.0)     # Σp²


# ---------------------------------------------------------------------------
# Sampler kernels
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    f=st.sampled_from([16, 256, 1024]),
    ab_t=st.floats(0.01, 0.999),
    ab_prev=st.floats(0.01, 1.0),
)
def test_ddim_step_matches_ref(f, ab_t, ab_prev):
    x = rand(31, (f,))
    e = rand(32, (f,))
    out = ddim.ddim_step(x, e, ab_t, ab_prev)
    expect = ref.ddim_step_ref(x, e, ab_t, ab_prev)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)


@settings(**SET)
@given(f=st.sampled_from([16, 1024]), dt=st.floats(0.001, 0.1))
def test_rf_step_matches_ref(f, dt):
    x = rand(33, (f,))
    v = rand(34, (f,))
    out = ddim.rf_step(x, v, dt)
    expect = ref.rf_step_ref(x, v, dt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-6)


def test_ddim_identity_when_ab_one():
    x = rand(35, (64,))
    e = rand(36, (64,))
    out = ddim.ddim_step(x, e, 1.0, 1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)
