"""Build-path training utilities: dataset properties, schedules, Adam."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train as T
from compile.configs import DIT_SIM, VIDEO_SIM


def test_samples_shape_and_range():
    y = jnp.arange(16) % 8
    x = T.make_samples(DIT_SIM, y, jax.random.PRNGKey(0))
    assert x.shape == (16, 256)
    assert float(x.min()) >= -1.0 and float(x.max()) <= 1.0


def test_samples_class_separation():
    """Same class → similar images; different classes → distinct."""
    key = jax.random.PRNGKey(1)
    y_a = jnp.zeros(8, jnp.int32)
    y_b = jnp.full((8,), 3, jnp.int32)
    xa = np.asarray(T.make_samples(DIT_SIM, y_a, key))
    xb = np.asarray(T.make_samples(DIT_SIM, y_b, key))
    within = np.abs(xa.mean(0) - xa).mean()
    across = np.abs(xa.mean(0) - xb).mean()
    assert across > within


def test_video_frames_drift_smoothly():
    y = jnp.zeros(4, jnp.int32)
    x = np.asarray(T.make_samples(VIDEO_SIM, y, jax.random.PRNGKey(2)))
    x = x.reshape(4, VIDEO_SIM.frames, -1)
    d01 = np.abs(x[:, 0] - x[:, 1]).mean()
    d03 = np.abs(x[:, 0] - x[:, 3]).mean()
    assert d01 > 0.0            # frames differ (motion)
    assert d03 >= d01 * 0.9     # and drift accumulates over time


def test_ddpm_schedule_monotone():
    ab = np.asarray(T.ddpm_alphas_bar(1000))
    assert ab.shape == (1000,)
    assert np.all(np.diff(ab) < 0)
    assert 0 < ab[-1] < ab[0] <= 1.0


def test_ddim_schedule_contract():
    s = T.ddim_schedule(DIT_SIM)
    assert len(s["t_model"]) == DIT_SIM.serve_steps
    # serve order: noisiest (largest t) first
    assert s["t_model"][0] > s["t_model"][-1]
    assert s["ab_prev"][-1] == 1.0
    # ab_prev[i] corresponds to ab_t[i+1]
    np.testing.assert_allclose(s["ab_prev"][:-1], s["ab_t"][1:], rtol=1e-6)


def test_rf_schedule_contract():
    cfg = dataclasses.replace(DIT_SIM, schedule="rf")
    s = T.rf_schedule(cfg)
    assert s["kind"] == "rf"
    assert len(s["t_model"]) == cfg.serve_steps
    assert s["dt"] == pytest.approx(1.0 / cfg.serve_steps)
    assert s["t_model"][0] == pytest.approx(1000.0)


def test_adam_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = T.adam_init(params)

    def loss(p):
        return jnp.sum((p["w"] - jnp.asarray([1.0, 2.0])) ** 2)

    for _ in range(400):
        g = jax.grad(loss)(params)
        params, opt = T.adam_step(params, g, opt, 5e-2)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 2.0], atol=1e-2)


def test_training_reduces_loss_quickly():
    cfg = dataclasses.replace(DIT_SIM, dim=32, depth=2, heads=2, t_freq_dim=16,
                              train_steps=30, train_batch=8)
    _, losses = T.train_model(cfg, log_every=29)
    assert losses[-1][1] < losses[0][1]
