"""L2 model invariants: scan/unroll equivalence, single-block and head
parity with the full pass (the contract the Rust verification path relies
on), patchify round-trips, conditioning behaviour."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import DIT_SIM, FLUX_SIM, VIDEO_SIM, ModelConfig

TINY = dataclasses.replace(DIT_SIM, dim=32, depth=3, heads=2, t_freq_dim=16)


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    # randomize the zero-init tensors so parity tests are non-trivial
    keys = jax.random.split(jax.random.PRNGKey(1), len(M.PARAM_NAMES))
    params = {
        n: p + 0.02 * jax.random.normal(k, p.shape)
        for (n, p), k in zip(params.items(), keys)
    }
    x = jax.random.normal(jax.random.PRNGKey(2), (2, TINY.frames * TINY.image_size ** 2))
    t = jnp.asarray([10.0, 400.0])
    y = jnp.asarray([1, 3], jnp.int32)
    return params, x, t, y


def test_scan_equals_unroll(setup):
    params, x, t, y = setup
    e1, b1 = M.full_fwd(params, x, t, y, TINY)
    e2, b2 = M.full_fwd(params, x, t, y, TINY, unroll=True)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-5)


def test_block_fwd_parity_every_layer(setup):
    """block_fwd(l, boundaries[l]) == boundaries[l+1] for every layer —
    the exact invariant SpeCa verification depends on."""
    params, x, t, y = setup
    _, bounds = M.full_fwd(params, x, t, y, TINY)
    for l in range(TINY.depth):
        out = M.block_fwd(params, jnp.int32(l), bounds[l], t, y, TINY)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(bounds[l + 1]), atol=1e-5,
            err_msg=f"layer {l}"
        )


def test_head_fwd_parity(setup):
    params, x, t, y = setup
    eps, bounds = M.full_fwd(params, x, t, y, TINY)
    out = M.head_fwd(params, bounds[TINY.depth], t, y, TINY)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eps), atol=1e-5)


def test_pallas_full_matches_ref_attention(setup):
    params, x, t, y = setup
    e1, _ = M.full_fwd(params, x, t, y, TINY, use_pallas=False)
    e2, _ = M.full_fwd(params, x, t, y, TINY, use_pallas=True)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-4)


@pytest.mark.parametrize("cfg", [DIT_SIM, FLUX_SIM, VIDEO_SIM], ids=lambda c: c.name)
def test_patchify_roundtrip(cfg):
    x = jax.random.normal(jax.random.PRNGKey(5), (3, cfg.frames * cfg.channels * cfg.image_size ** 2))
    tok = M.patchify(x, cfg)
    assert tok.shape == (3, cfg.tokens, cfg.patch_dim)
    back = M.unpatchify(tok, cfg)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=0)


def test_adaln_zero_init_is_identity():
    """With zero-init adaLN and head, blocks are identity and eps ≡ 0."""
    params = M.init_params(TINY, jax.random.PRNGKey(7))
    x = jax.random.normal(jax.random.PRNGKey(8), (1, TINY.frames * TINY.image_size ** 2))
    t = jnp.asarray([100.0])
    y = jnp.asarray([0], jnp.int32)
    eps, bounds = M.full_fwd(params, x, t, y, TINY)
    np.testing.assert_allclose(np.asarray(eps), 0.0, atol=1e-6)
    for l in range(TINY.depth):
        np.testing.assert_allclose(
            np.asarray(bounds[l]), np.asarray(bounds[l + 1]), atol=1e-6
        )


def test_conditioning_changes_output(setup):
    params, x, t, y = setup
    e1, _ = M.full_fwd(params, x, t, y, TINY)
    e2, _ = M.full_fwd(params, x, t, jnp.asarray([2, 0], jnp.int32), TINY)
    e3, _ = M.full_fwd(params, x, jnp.asarray([500.0, 90.0]), y, TINY)
    assert float(jnp.max(jnp.abs(e1 - e2))) > 1e-6
    assert float(jnp.max(jnp.abs(e1 - e3))) > 1e-6


def test_timestep_embedding_distinct():
    e = M.timestep_embedding(jnp.asarray([0.0, 1.0, 500.0, 999.0]), 64)
    assert e.shape == (4, 64)
    d = np.asarray(jnp.abs(e[:, None] - e[None, :]).sum(-1))
    for i in range(4):
        for j in range(i + 1, 4):
            assert d[i, j] > 0.1


def test_param_shapes_cover_names():
    for cfg in (DIT_SIM, FLUX_SIM, VIDEO_SIM):
        shapes = M.param_shapes(cfg)
        assert set(shapes.keys()) == set(M.PARAM_NAMES)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        for n in M.PARAM_NAMES:
            assert tuple(params[n].shape) == tuple(shapes[n]), n


def test_classifier_shapes():
    p = M.cls_init(256, 64, 32, 8, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 256))
    logits, feats = M.cls_fwd(p, x)
    assert logits.shape == (5, 8)
    assert feats.shape == (5, 32)
