"""AOT compile path: train → lower → serialize artifacts for the Rust runtime.

Run once by ``make artifacts`` (no-op when outputs are newer than inputs).
Python never appears on the request path; everything the Rust coordinator
needs lands in ``artifacts/``:

* ``<model>/weights.bin``    — trained parameters (mini-safetensors, see
                               ``write_tensors``; rust/src/weights mirrors it)
* ``<model>/*.hlo.txt``      — HLO **text** per entry point × batch bucket.
  Text, not ``.serialize()``: jax ≥ 0.5 emits protos with 64-bit instruction
  ids that xla_extension 0.5.1 rejects; the text parser reassigns ids
  (see /opt/xla-example/README.md).
* ``<model>/goldens.bin``    — reference traces for Rust integration tests
* ``classifier/...``         — metrics classifier + FID reference stats
* ``manifest.json``          — shapes, schedules, FLOPs model, artifact map
"""

import argparse
import hashlib
import json
import os
import struct
import sys
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .configs import CLASSIFIER, CONFIGS, ModelConfig
from .kernels import ddim as kddim
from .kernels import ref as kref
from .kernels import taylor as ktaylor
from .kernels import verify as kverify

MANIFEST_VERSION = 3


# ---------------------------------------------------------------------------
# HLO text lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_to_file(fn, arg_specs, path: str) -> int:
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*arg_specs))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Tensor container (mini-safetensors; rust/src/weights/mod.rs is the reader)
# ---------------------------------------------------------------------------

MAGIC = b"SPCA"
DTYPE_F32, DTYPE_I32 = 0, 1


def write_tensors(path: str, tensors: List):
    """tensors: list of (name, np.ndarray[f32|i32])."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float32:
                dt = DTYPE_F32
            elif arr.dtype == np.int32:
                dt = DTYPE_I32
            else:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dt, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


# ---------------------------------------------------------------------------
# Per-model pipeline
# ---------------------------------------------------------------------------

def config_hash(cfg: ModelConfig) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def train_or_load(cfg: ModelConfig, out_dir: str, force: bool):
    """Training is cached in <out>/<model>/params.npz keyed by config hash."""
    cache = os.path.join(out_dir, cfg.name, "params.npz")
    h = config_hash(cfg)
    if not force and os.path.exists(cache):
        data = np.load(cache, allow_pickle=False)
        if data.get("__hash__") is not None and str(data["__hash__"]) == h:
            print(f"[{cfg.name}] using cached weights ({cache})")
            params = {n: jnp.asarray(data[n]) for n in M.PARAM_NAMES}
            losses = data["__losses__"].tolist()
            return params, losses
    print(f"[{cfg.name}] training ({cfg.train_steps} steps)...")
    params, losses = T.train_model(cfg)
    os.makedirs(os.path.dirname(cache), exist_ok=True)
    np.savez(cache, __hash__=h, __losses__=np.asarray(losses, np.float32),
             **{n: np.asarray(v) for n, v in params.items()})
    return params, losses


def lower_model_artifacts(cfg: ModelConfig, out_dir: str) -> Dict:
    """Lower every entry point for every batch bucket. Returns artifact map
    of repo-relative paths."""
    d = cfg.name
    os.makedirs(os.path.join(out_dir, d), exist_ok=True)
    latent = cfg.frames * cfg.channels * cfg.image_size ** 2
    T_, D, L = cfg.tokens, cfg.dim, cfg.depth
    wspecs = [spec(s) for s in (M.param_shapes(cfg)[n] for n in M.PARAM_NAMES)]
    arts: Dict = {"full": {}, "full_eps": {}, "block": {}, "head": {}, "full_pallas": {}}

    for B in cfg.buckets:
        xs, ts = spec([B, latent]), spec([B])
        ys = spec([B], jnp.int32)
        fs = spec([B, T_, D])

        def full(*a):
            p = M.unflatten_params(a[:len(M.PARAM_NAMES)])
            return M.full_fwd(p, *a[len(M.PARAM_NAMES):], cfg=cfg)

        def blockf(*a):
            p = M.unflatten_params(a[:len(M.PARAM_NAMES)])
            layer, feat, t, y = a[len(M.PARAM_NAMES):]
            return (M.block_fwd(p, layer, feat, t, y, cfg),)

        def headf(*a):
            p = M.unflatten_params(a[:len(M.PARAM_NAMES)])
            return (M.head_fwd(p, *a[len(M.PARAM_NAMES):], cfg=cfg),)

        f = os.path.join(d, f"full_b{B}.hlo.txt")
        lower_to_file(full, wspecs + [xs, ts, ys], os.path.join(out_dir, f))
        arts["full"][str(B)] = f

        # eps-only variant: skips the [L+1,B,T,D] boundary output transfer
        # for policies that never read the feature cache (perf pass finding)
        def full_eps(*a):
            p = M.unflatten_params(a[:len(M.PARAM_NAMES)])
            eps, _ = M.full_fwd(p, *a[len(M.PARAM_NAMES):], cfg=cfg)
            return (eps,)

        f = os.path.join(d, f"full_eps_b{B}.hlo.txt")
        lower_to_file(full_eps, wspecs + [xs, ts, ys], os.path.join(out_dir, f))
        arts["full_eps"][str(B)] = f

        f = os.path.join(d, f"block_b{B}.hlo.txt")
        lower_to_file(blockf, wspecs + [spec([], jnp.int32), fs, ts, ys],
                      os.path.join(out_dir, f))
        arts["block"][str(B)] = f

        f = os.path.join(d, f"head_b{B}.hlo.txt")
        lower_to_file(headf, wspecs + [fs, ts, ys], os.path.join(out_dir, f))
        arts["head"][str(B)] = f

    # Pallas-attention variant of the full pass (bucket 1): used by the L1
    # structure benches and the perf comparison in EXPERIMENTS.md §Perf.
    def full_pallas(*a):
        p = M.unflatten_params(a[:len(M.PARAM_NAMES)])
        return M.full_fwd(p, *a[len(M.PARAM_NAMES):], cfg=cfg, use_pallas=True)

    f = os.path.join(d, "full_pallas_b1.hlo.txt")
    lower_to_file(full_pallas, wspecs + [spec([1, latent]), spec([1]), spec([1], jnp.int32)],
                  os.path.join(out_dir, f))
    arts["full_pallas"]["1"] = f

    # Standalone L1 kernel artifacts (parity-checked against the native Rust
    # implementations; also used by kernel micro-benches).
    feat_flat = T_ * D
    f = os.path.join(d, "taylor_predict_m3.hlo.txt")
    lower_to_file(lambda fac, k, n: (ktaylor.taylor_predict(fac, k, n),),
                  [spec([3, feat_flat]), spec([]), spec([])], os.path.join(out_dir, f))
    arts["taylor_predict"] = f

    f = os.path.join(d, "taylor_update_m3.hlo.txt")
    lower_to_file(lambda fac, ft: (ktaylor.taylor_update(fac, ft),),
                  [spec([3, feat_flat]), spec([feat_flat])], os.path.join(out_dir, f))
    arts["taylor_update"] = f

    f = os.path.join(d, "verify_stats.hlo.txt")
    lower_to_file(lambda a, b: (kverify.verify_stats(a, b),),
                  [spec([feat_flat]), spec([feat_flat])], os.path.join(out_dir, f))
    arts["verify_stats"] = f

    f = os.path.join(d, "step.hlo.txt")
    if cfg.schedule == "ddim":
        lower_to_file(lambda x, e, a, b: (kddim.ddim_step(x, e, a, b),),
                      [spec([latent]), spec([latent]), spec([]), spec([])],
                      os.path.join(out_dir, f))
    else:
        lower_to_file(lambda x, v, dt: (kddim.rf_step(x, v, dt),),
                      [spec([latent]), spec([latent]), spec([])],
                      os.path.join(out_dir, f))
    arts["step"] = f
    return arts


def make_goldens(cfg: ModelConfig, params, out_dir: str):
    """Reference traces the Rust integration tests replay bit-for-bit-ish
    (1e-3 tolerance across the PJRT text round-trip)."""
    latent = cfg.frames * cfg.channels * cfg.image_size ** 2
    sched = T.schedule_for(cfg)
    key = jax.random.PRNGKey(1234)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (1, latent), jnp.float32)
    y = jnp.asarray([3 % cfg.num_classes], jnp.int32)
    x_T = np.asarray(x[0]).copy()

    eps_all, x_all = [], []
    boundaries0 = None
    for i in range(cfg.serve_steps):
        t = jnp.asarray([sched["t_model"][i]], jnp.float32)
        eps, bounds = M.full_fwd(params, x, t, y, cfg)
        if i == 0:
            boundaries0 = np.asarray(bounds[:, 0])      # [L+1, T, D]
        eps_all.append(np.asarray(eps[0]))
        if sched["kind"] == "ddim":
            x = kref.ddim_step_ref(x, eps, sched["ab_t"][i], sched["ab_prev"][i])
        else:
            x = kref.rf_step_ref(x, eps, sched["dt"])
        x_all.append(np.asarray(x[0]))

    # single-block + head parity points at the first step
    v = cfg.depth - 1
    t0 = jnp.asarray([sched["t_model"][0]], jnp.float32)
    blk_out = M.block_fwd(params, jnp.int32(v), jnp.asarray(boundaries0[v][None]), t0, y, cfg)
    head_out = M.head_fwd(params, jnp.asarray(boundaries0[cfg.depth][None]), t0, y, cfg)

    tensors = [
        ("x_T", x_T.astype(np.float32)),
        ("y", np.asarray([3 % cfg.num_classes], np.int32)),
        ("eps_all", np.stack(eps_all).astype(np.float32)),
        ("x_all", np.stack(x_all).astype(np.float32)),
        ("boundaries0", boundaries0.astype(np.float32)),
        ("verify_layer", np.asarray([v], np.int32)),
        ("block_out", np.asarray(blk_out[0], np.float32)),
        ("head_out", np.asarray(head_out[0], np.float32)),
    ]
    path = os.path.join(out_dir, cfg.name, "goldens.bin")
    write_tensors(path, tensors)
    return os.path.join(cfg.name, "goldens.bin")


def build_model(cfg: ModelConfig, out_dir: str, force_train: bool) -> Dict:
    params, losses = train_or_load(cfg, out_dir, force_train)
    weights_rel = os.path.join(cfg.name, "weights.bin")
    write_tensors(os.path.join(out_dir, weights_rel),
                  [(n, np.asarray(params[n], np.float32)) for n in M.PARAM_NAMES])
    print(f"[{cfg.name}] lowering artifacts...", flush=True)
    arts = lower_model_artifacts(cfg, out_dir)
    goldens_rel = make_goldens(cfg, params, out_dir)
    latent = cfg.frames * cfg.channels * cfg.image_size ** 2
    entry = {
        "config": {
            "name": cfg.name, "image_size": cfg.image_size, "channels": cfg.channels,
            "patch": cfg.patch, "dim": cfg.dim, "depth": cfg.depth, "heads": cfg.heads,
            "mlp_ratio": cfg.mlp_ratio, "num_classes": cfg.num_classes,
            "frames": cfg.frames, "schedule": cfg.schedule,
            "serve_steps": cfg.serve_steps, "train_timesteps": cfg.train_timesteps,
            "tokens": cfg.tokens, "latent_dim": latent, "buckets": cfg.buckets,
        },
        "schedule": T.schedule_for(cfg),
        "params": [{"name": n, "shape": list(M.param_shapes(cfg)[n])}
                   for n in M.PARAM_NAMES],
        "weights": weights_rel,
        "goldens": goldens_rel,
        "artifacts": arts,
        "flops": {
            "full_step": {str(b): cfg.full_step_flops(b) for b in cfg.buckets},
            "block": {str(b): cfg.block_flops(b) for b in cfg.buckets},
            "head": {str(b): cfg.head_flops(b) + cfg.embed_flops(b) for b in cfg.buckets},
            "predict_per_order": cfg.predict_flops(1, 1) // 2,
        },
        "train_losses": losses,
    }
    return entry


def build_classifier(out_dir: str, force_train: bool) -> Dict:
    from .configs import DIT_SIM
    cfg = DIT_SIM
    cdir = os.path.join(out_dir, "classifier")
    os.makedirs(cdir, exist_ok=True)
    cache = os.path.join(cdir, "params.npz")
    if not force_train and os.path.exists(cache):
        data = np.load(cache)
        params = {n: jnp.asarray(data[n]) for n in M.CLS_PARAM_NAMES}
        acc = float(data["__acc__"])
        print(f"[classifier] using cached weights (acc {acc:.3f})")
    else:
        print("[classifier] training...")
        params, acc = T.train_classifier(cfg)
        np.savez(cache, __acc__=acc, **{n: np.asarray(v) for n, v in params.items()})

    mu, cov, mu_p, cov_p = T.reference_stats(params, cfg)
    latent = cfg.image_size * cfg.image_size * cfg.channels
    tensors = [(n, np.asarray(params[n], np.float32)) for n in M.CLS_PARAM_NAMES]
    tensors += [("fid_mu", mu), ("fid_cov", cov), ("sfid_mu", mu_p), ("sfid_cov", cov_p)]
    write_tensors(os.path.join(cdir, "weights.bin"), tensors)

    arts = {}
    cc = CLASSIFIER
    cls_shapes = M.cls_param_shapes(latent, cc.hidden, cc.feat_dim, cc.num_classes)
    cspecs = [spec(cls_shapes[n]) for n in M.CLS_PARAM_NAMES]
    for B in (1, 16, 64):
        def clsf(*a):
            p = dict(zip(M.CLS_PARAM_NAMES, a[:len(M.CLS_PARAM_NAMES)]))
            return M.cls_fwd(p, a[-1])
        f = os.path.join("classifier", f"cls_b{B}.hlo.txt")
        lower_to_file(clsf, cspecs + [spec([B, latent])], os.path.join(out_dir, f))
        arts[str(B)] = f

    # goldens
    k1, k2 = jax.random.split(jax.random.PRNGKey(99))
    y = jax.random.randint(k1, (4,), 0, cc.num_classes)
    frame_cfg = cfg
    x = T.make_samples(ModelConfig(name="_f", image_size=cfg.image_size,
                                   channels=cfg.channels, frames=1,
                                   dim=cfg.dim, depth=cfg.depth, heads=cfg.heads),
                       y, k2)
    logits, feats = M.cls_fwd(params, x)
    write_tensors(os.path.join(cdir, "goldens.bin"), [
        ("cls_in", np.asarray(x, np.float32)),
        ("cls_logits", np.asarray(logits, np.float32)),
        ("cls_feats", np.asarray(feats, np.float32)),
    ])

    return {
        "weights": "classifier/weights.bin",
        "goldens": "classifier/goldens.bin",
        "artifacts": arts,
        "params": [{"name": n, "shape": list(cls_shapes[n])} for n in M.CLS_PARAM_NAMES],
        "acc": acc,
        "feat_dim": cc.feat_dim,
        "num_classes": cc.num_classes,
        "latent_dim": latent,
        "buckets": [1, 16, 64],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="dit-sim,flux-sim,video-sim")
    ap.add_argument("--force-train", action="store_true")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    manifest = {"version": MANIFEST_VERSION, "models": {}, "classifier": None}
    for name in args.models.split(","):
        cfg = CONFIGS[name.strip()]
        manifest["models"][cfg.name] = build_model(cfg, out, args.force_train)
    manifest["classifier"] = build_classifier(out, args.force_train)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(out, 'manifest.json')}")


if __name__ == "__main__":
    main()
