"""Model + schedule configurations for the SpeCa reproduction.

Three simulated backbones stand in for the paper's FLUX.1-dev / DiT-XL/2 /
HunyuanVideo (see DESIGN.md §2 for the substitution argument):

* ``dit-sim``   — class-conditional image DiT (paper Table 3, DDIM 50 steps)
* ``flux-sim``  — "text"-conditional image DiT on a rectified-flow schedule
                  (paper Table 1; prompts simulated as learned embeddings)
* ``video-sim`` — 4-frame video DiT, rectified flow (paper Table 2)

All are trained from scratch at build time on the synthetic shapes corpus
(train.py) so feature trajectories have realistic smoothness; the SpeCa
mechanism (forecast-then-verify) only depends on those dynamics, not scale.
"""

from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    name: str
    image_size: int = 16
    channels: int = 1
    patch: int = 2
    dim: int = 128
    depth: int = 8
    heads: int = 4
    mlp_ratio: int = 4
    num_classes: int = 8          # class labels (dit-sim) or prompt ids
    frames: int = 1               # >1 => video (tokens = frames * patches)
    schedule: str = "ddim"        # "ddim" (DDPM-trained) | "rf" (rectified flow)
    serve_steps: int = 50
    train_timesteps: int = 1000   # DDPM only
    t_freq_dim: int = 128         # sinusoidal embedding width
    # AOT batch buckets the Rust batcher may use.
    buckets: List[int] = field(default_factory=lambda: [1, 2, 4, 8])
    # Training hyper-parameters (build path only). Sized for the 2-core CPU
    # build environment; env SPECA_TRAIN_SCALE multiplies step counts.
    train_steps: int = 900
    train_batch: int = 32
    lr: float = 2e-3

    @property
    def tokens_per_frame(self) -> int:
        return (self.image_size // self.patch) ** 2

    @property
    def tokens(self) -> int:
        return self.frames * self.tokens_per_frame

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    # ------------------------------------------------------------------
    # Analytic FLOPs model (multiply-accumulate counted as 2 flops),
    # recorded in the manifest and consumed by rust/src/metrics/flops.rs.
    # ------------------------------------------------------------------
    def block_flops(self, batch: int) -> int:
        T, D, M = self.tokens, self.dim, self.mlp_ratio
        per_tok = (
            2 * D * 3 * D          # qkv projection
            + 2 * D * D            # output projection
            + 2 * D * M * D * 2    # MLP (two matmuls)
            + 2 * D * 6 * D        # adaLN modulation from conditioning
        )
        attn = 2 * 2 * T * T * D   # QK^T and PV
        return batch * (T * per_tok + attn)

    def head_flops(self, batch: int) -> int:
        T, D = self.tokens, self.dim
        return batch * T * (2 * D * self.patch_dim + 2 * D * 2 * D)

    def embed_flops(self, batch: int) -> int:
        T, D = self.tokens, self.dim
        return batch * (T * 2 * self.patch_dim * D + 2 * self.t_freq_dim * D + 2 * D * D)

    def full_step_flops(self, batch: int) -> int:
        return self.embed_flops(batch) + self.depth * self.block_flops(batch) + self.head_flops(batch)

    def verify_flops(self, batch: int) -> int:
        """One transformer block (paper: gamma ~= 1/depth of a full pass)."""
        return self.block_flops(batch)

    def predict_flops(self, batch: int, order: int, taps: int = 3) -> int:
        feat = self.tokens * self.dim
        return batch * taps * feat * 2 * (order + 1)


def _scaled(steps: int) -> int:
    import os
    return max(50, int(steps * float(os.environ.get("SPECA_TRAIN_SCALE", "1.0"))))


DIT_SIM = ModelConfig(
    name="dit-sim",
    dim=128, depth=8, heads=4, num_classes=8,
    schedule="ddim", train_steps=_scaled(900),
)

FLUX_SIM = ModelConfig(
    name="flux-sim",
    dim=96, depth=6, heads=4, num_classes=32,  # 32 "prompts"
    schedule="rf", train_steps=_scaled(700),
)

VIDEO_SIM = ModelConfig(
    name="video-sim",
    dim=96, depth=6, heads=4, num_classes=16, frames=4,
    schedule="rf", train_steps=_scaled(450), train_batch=16,
)

CONFIGS = {c.name: c for c in (DIT_SIM, FLUX_SIM, VIDEO_SIM)}


@dataclass(frozen=True)
class ClassifierConfig:
    """Tiny classifier trained on the shapes corpus; provides FID features
    (penultimate layer) and class posteriors for the Inception-style score."""
    hidden: int = 128
    feat_dim: int = 64
    num_classes: int = 8
    train_steps: int = 1500
    train_batch: int = 256
    lr: float = 2e-3


CLASSIFIER = ClassifierConfig()
