"""Build-time training on a synthetic shapes corpus (no external data).

The paper serves pretrained FLUX / DiT-XL/2 / HunyuanVideo checkpoints; we
have no offline checkpoints, so `make artifacts` trains each simulated
backbone from scratch for a few thousand steps (DESIGN.md §2). What SpeCa
needs from the model is *realistic feature-trajectory smoothness across
denoising timesteps*, which a converged tiny DiT exhibits.

Also trains the metrics classifier (FID features + Inception-style score)
and computes the reference feature statistics used by the Rust FID.

Everything is hand-rolled jax (no optax on this image): Adam + cosine LR.
"""

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import CLASSIFIER, ModelConfig

# ---------------------------------------------------------------------------
# Synthetic shapes corpus: 16×16 grayscale, 8 base classes, parameterized
# so every draw is distinct. Values in [-1, 1].
# ---------------------------------------------------------------------------

def _grid(img: int):
    c = (jnp.arange(img, dtype=jnp.float32) - (img - 1) / 2) / img * 2.0
    return jnp.meshgrid(c, c, indexing="ij")


def shapes_frame(base_class, p1, p2, img: int = 16):
    """One 16×16 frame. base_class in 0..7; p1, p2 ∈ [0,1] shape params."""
    yy, xx = _grid(img)

    def blob(cx, cy, s):
        return jnp.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * s ** 2)))

    freq = 2.0 + 4.0 * p1
    phase = 2 * math.pi * p2
    variants = jnp.stack([
        2 * blob(-0.4 + 0.3 * p1, -0.4 + 0.3 * p2, 0.25) - 1,         # 0 blob TL
        2 * blob(0.4 - 0.3 * p1, 0.4 - 0.3 * p2, 0.25) - 1,           # 1 blob BR
        jnp.sin(freq * math.pi * xx + phase),                          # 2 v-stripes
        jnp.sin(freq * math.pi * yy + phase),                          # 3 h-stripes
        jnp.cos(8.0 * jnp.sqrt(xx ** 2 + yy ** 2 + 1e-6) - 4 * p1),    # 4 rings
        jnp.tanh(2.0 * (xx * (0.5 + p1) + yy * (0.5 + p2))),           # 5 gradient
        jnp.sign(jnp.sin(freq * math.pi * xx) * jnp.sin(freq * math.pi * yy)) * 0.8,  # 6 checker
        2 * jnp.maximum(blob(0.0, 0.0, 0.08 + 0.1 * p1) ** 0.5,
                        blob(0.6 * (p2 - 0.5), 0.0, 0.12)) - 1,        # 7 dot pair
    ])
    return variants[base_class]


def make_samples(cfg: ModelConfig, y, key):
    """y: [B] condition ids -> x0 [B, latent]. Videos translate the shape
    parameters across frames (temporal consistency for VBench*)."""
    B = y.shape[0]
    base = jnp.mod(y, 8)
    k1, k2, k3 = jax.random.split(key, 3)
    p1 = jax.random.uniform(k1, (B,))
    p2 = jax.random.uniform(k2, (B,))
    # condition id deterministically biases the shape params so different
    # "prompts" (flux/video sims) are visually distinct beyond base class
    p1 = 0.5 * p1 + 0.5 * (jnp.asarray(y, jnp.float32) % 17.0) / 17.0
    frames = []
    for f in range(cfg.frames):
        drift = 0.15 * f
        fr = jax.vmap(lambda b, a1, a2: shapes_frame(b, jnp.clip(a1 + drift, 0, 1), a2,
                                                     cfg.image_size))(base, p1, p2)
        frames.append(fr)
    x = jnp.stack(frames, axis=1)  # [B, F, H, W]
    noise = 0.05 * jax.random.normal(k3, x.shape)
    x = jnp.clip(x + noise, -1.0, 1.0)
    return x.reshape(B, cfg.frames * cfg.channels * cfg.image_size * cfg.image_size)


# ---------------------------------------------------------------------------
# Noise schedules
# ---------------------------------------------------------------------------

def ddpm_alphas_bar(train_timesteps: int):
    betas = jnp.linspace(1e-4, 2e-2, train_timesteps, dtype=jnp.float32)
    return jnp.cumprod(1.0 - betas)


def ddim_schedule(cfg: ModelConfig) -> Dict:
    """The 50-step serve-time DDIM subsequence: per step the model-time
    value t, ᾱ_t and ᾱ_prev (next point toward data; last gets ᾱ=1)."""
    ab = ddpm_alphas_bar(cfg.train_timesteps)
    idx = np.linspace(0, cfg.train_timesteps - 1, cfg.serve_steps).round().astype(int)[::-1]
    ab_t = np.asarray(ab)[idx]
    ab_prev = np.concatenate([np.asarray(ab)[idx[1:]], [1.0]])
    return {
        "kind": "ddim",
        "t_model": idx.astype(np.float32).tolist(),
        "ab_t": ab_t.astype(np.float32).tolist(),
        "ab_prev": ab_prev.astype(np.float32).tolist(),
    }


def rf_schedule(cfg: ModelConfig) -> Dict:
    """Rectified flow: t from 1 → 0 over serve_steps Euler steps; the model
    is fed t·1000 for embedding resolution."""
    ts = np.linspace(1.0, 1.0 / cfg.serve_steps, cfg.serve_steps)
    return {
        "kind": "rf",
        "t_model": (ts * 1000.0).astype(np.float32).tolist(),
        "dt": float(1.0 / cfg.serve_steps),
    }


def schedule_for(cfg: ModelConfig) -> Dict:
    return ddim_schedule(cfg) if cfg.schedule == "ddim" else rf_schedule(cfg)


# ---------------------------------------------------------------------------
# Adam (hand-rolled; no optax on this image)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps), params, m, v)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Diffusion training
# ---------------------------------------------------------------------------

def train_model(cfg: ModelConfig, seed: int = 0, log_every: int = 200):
    key = jax.random.PRNGKey(seed)
    key, pk = jax.random.split(key)
    params = M.init_params(cfg, pk)
    opt = adam_init(params)
    ab = ddpm_alphas_bar(cfg.train_timesteps) if cfg.schedule == "ddim" else None

    def loss_fn(p, x0, y, t_raw, noise):
        if cfg.schedule == "ddim":
            a = ab[t_raw][:, None]
            xt = jnp.sqrt(a) * x0 + jnp.sqrt(1 - a) * noise
            target = noise
            t_model = t_raw.astype(jnp.float32)
        else:
            tt = t_raw.astype(jnp.float32)[:, None]
            xt = (1 - tt) * x0 + tt * noise
            target = noise - x0                    # velocity toward noise
            t_model = t_raw.astype(jnp.float32) * 1000.0
        pred, _ = M.full_fwd(p, xt, t_model, y, cfg)
        return jnp.mean((pred - target) ** 2)

    @jax.jit
    def step(p, o, key, lr):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        y = jax.random.randint(k1, (cfg.train_batch,), 0, cfg.num_classes)
        x0 = make_samples(cfg, y, k2)
        noise = jax.random.normal(k3, x0.shape)
        if cfg.schedule == "ddim":
            t_raw = jax.random.randint(k4, (cfg.train_batch,), 0, cfg.train_timesteps)
        else:
            t_raw = jax.random.uniform(k4, (cfg.train_batch,))
        l, g = jax.value_and_grad(loss_fn)(p, x0, y, t_raw, noise)
        p, o = adam_step(p, g, o, lr)
        return p, o, l

    losses = []
    for i in range(cfg.train_steps):
        key, sk = jax.random.split(key)
        lr = cfg.lr * 0.5 * (1 + math.cos(math.pi * i / cfg.train_steps))
        params, opt, l = step(params, opt, sk, lr)
        if i % log_every == 0 or i == cfg.train_steps - 1:
            losses.append((i, float(l)))
            print(f"  [{cfg.name}] step {i:5d} loss {float(l):.4f} lr {lr:.2e}", flush=True)
    return params, losses


# ---------------------------------------------------------------------------
# Classifier training (FID features + IS posteriors)
# ---------------------------------------------------------------------------

def train_classifier(cfg: ModelConfig, seed: int = 7):
    """Trains on single frames of the shapes corpus (8 base classes)."""
    cc = CLASSIFIER
    latent = cfg.image_size * cfg.image_size * cfg.channels
    key = jax.random.PRNGKey(seed)
    key, pk = jax.random.split(key)
    params = M.cls_init(latent, cc.hidden, cc.feat_dim, cc.num_classes, pk)
    opt = adam_init(params)
    frame_cfg = ModelConfig(name="_frame", image_size=cfg.image_size,
                            channels=cfg.channels, frames=1,
                            dim=cfg.dim, depth=cfg.depth, heads=cfg.heads)

    def loss_fn(p, x, y):
        logits, _ = M.cls_fwd(p, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(p, o, key, lr):
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (cc.train_batch,), 0, cc.num_classes)
        x = make_samples(frame_cfg, y, k2)
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o = adam_step(p, g, o, lr)
        return p, o, l

    for i in range(cc.train_steps):
        key, sk = jax.random.split(key)
        lr = cc.lr * 0.5 * (1 + math.cos(math.pi * i / cc.train_steps))
        params, opt, l = step(params, opt, sk, lr)
        if i % 300 == 0 or i == cc.train_steps - 1:
            print(f"  [classifier] step {i:5d} loss {float(l):.4f}", flush=True)

    # held-out accuracy
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    y = jax.random.randint(k1, (2048,), 0, cc.num_classes)
    x = make_samples(frame_cfg, y, k2)
    logits, feats = M.cls_fwd(params, x)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == y))
    print(f"  [classifier] held-out acc {acc:.3f}")
    return params, acc


def reference_stats(cls_params, cfg: ModelConfig, n: int = 4096, seed: int = 11):
    """FID reference: classifier-feature μ/Σ of a held-out real sample set,
    plus raw-pixel μ/Σ (sFID* analog) of the same set."""
    frame_cfg = ModelConfig(name="_frame", image_size=cfg.image_size,
                            channels=cfg.channels, frames=1,
                            dim=cfg.dim, depth=cfg.depth, heads=cfg.heads)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    y = jax.random.randint(k1, (n,), 0, CLASSIFIER.num_classes)
    x = make_samples(frame_cfg, y, k2)
    _, feats = M.cls_fwd(cls_params, x)
    feats = np.asarray(feats, np.float64)
    mu = feats.mean(0)
    cov = np.cov(feats, rowvar=False)
    # raw-pixel stats on an 8×8 downsample (keeps Σ small for sFID*)
    xs = np.asarray(x).reshape(n, cfg.image_size, cfg.image_size)
    ds = xs.reshape(n, 8, cfg.image_size // 8, 8, cfg.image_size // 8).mean((2, 4)).reshape(n, 64)
    mu_p = ds.mean(0)
    cov_p = np.cov(ds, rowvar=False)
    return (mu.astype(np.float32), cov.astype(np.float32),
            mu_p.astype(np.float32), cov_p.astype(np.float32))
