"""L2: DiT diffusion transformer in JAX (build path only).

Scan-based adaLN-zero DiT (Peebles & Xie) sized by ``configs.ModelConfig``.
Block weights are stacked along a leading ``depth`` axis so (a) the whole
forward lowers to a compact ``lax.scan`` HLO and (b) the Rust side passes
~22 tensors regardless of depth, and the verification entry point can pick
a layer with a *runtime* ``layer_idx : i32`` via dynamic slicing — the
paper's single-block verification (γ ≈ 1/depth of a full pass).

Entry points exported by aot.py:

* ``full_fwd``  (x[B,F_lat], t[B], y[B]) → (eps[B,F_lat], boundaries[L+1,B,T,D])
* ``block_fwd`` (layer i32, feat[B,T,D], t[B], y[B]) → feat'[B,T,D]
* ``head_fwd``  (feat[B,T,D], t[B], y[B]) → eps[B,F_lat]

Latents are flat ``[B, frames·channels·H·W]`` at the interface (keeps the
Rust tensor plumbing trivial); patchify/unpatchify happen inside.
Attention goes through the L1 Pallas kernel when ``use_pallas=True``
(exported as the ``*_pallas`` artifact variants; the default variants use
the fused-jnp path — see DESIGN.md §9 on the interpret-mode trade-off).
"""

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import attention as attn_kernel
from .kernels import ref as kref

# Canonical parameter order — Rust weights.bin and all AOT signatures
# follow this list exactly.
PARAM_NAMES: List[str] = [
    "patch_w", "patch_b", "pos_emb",
    "t_w1", "t_b1", "t_w2", "t_b2",
    "y_emb",
    "blk_adaln_w", "blk_adaln_b",
    "blk_qkv_w", "blk_qkv_b", "blk_proj_w", "blk_proj_b",
    "blk_mlp_w1", "blk_mlp_b1", "blk_mlp_w2", "blk_mlp_b2",
    "head_adaln_w", "head_adaln_b", "head_w", "head_b",
]

BLOCK_PARAM_NAMES = [n for n in PARAM_NAMES if n.startswith("blk_")]


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D, L, M, T = cfg.dim, cfg.depth, cfg.mlp_ratio, cfg.tokens
    pd, fd = cfg.patch_dim, cfg.t_freq_dim
    return {
        "patch_w": (pd, D), "patch_b": (D,), "pos_emb": (T, D),
        "t_w1": (fd, D), "t_b1": (D,), "t_w2": (D, D), "t_b2": (D,),
        "y_emb": (cfg.num_classes, D),
        "blk_adaln_w": (L, D, 6 * D), "blk_adaln_b": (L, 6 * D),
        "blk_qkv_w": (L, D, 3 * D), "blk_qkv_b": (L, 3 * D),
        "blk_proj_w": (L, D, D), "blk_proj_b": (L, D),
        "blk_mlp_w1": (L, D, M * D), "blk_mlp_b1": (L, M * D),
        "blk_mlp_w2": (L, M * D, D), "blk_mlp_b2": (L, D),
        "head_adaln_w": (D, 2 * D), "head_adaln_b": (2 * D,),
        "head_w": (D, pd), "head_b": (pd,),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """DiT-style init: scaled-normal weights; adaLN modulation and final
    head zero-initialized (adaLN-zero) so blocks start as identity."""
    shapes = param_shapes(cfg)
    zero_init = {"blk_adaln_w", "blk_adaln_b", "head_adaln_w",
                 "head_adaln_b", "head_w", "head_b"}
    params = {}
    keys = jax.random.split(key, len(PARAM_NAMES))
    for name, k in zip(PARAM_NAMES, keys):
        shp = shapes[name]
        if name in zero_init or (name.endswith("_b")):
            params[name] = jnp.zeros(shp, jnp.float32)
        elif name in ("pos_emb", "y_emb"):
            params[name] = 0.02 * jax.random.normal(k, shp, jnp.float32)
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[0]
            params[name] = jax.random.normal(k, shp, jnp.float32) / math.sqrt(fan_in)
    return params


def flatten_params(params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[n] for n in PARAM_NAMES]


def unflatten_params(flat) -> Dict[str, jnp.ndarray]:
    return dict(zip(PARAM_NAMES, flat))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def timestep_embedding(t, freq_dim: int):
    """Sinusoidal embedding of (possibly fractional) timesteps. t: [B]."""
    half = freq_dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def cond_embed(p: Dict, t, y, cfg: ModelConfig):
    """Conditioning vector c = MLP(sin-embed(t)) + y_emb[y]. -> [B, D]."""
    te = timestep_embedding(t, cfg.t_freq_dim)
    h = jax.nn.silu(te @ p["t_w1"] + p["t_b1"])
    h = h @ p["t_w2"] + p["t_b2"]
    return h + p["y_emb"][y]


def _ln(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6)


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def _mha_dispatch(q, k, v, use_pallas: bool):
    return attn_kernel.mha(q, k, v) if use_pallas else kref.mha_ref(q, k, v)


def dit_block(bp: Dict, x, c, cfg: ModelConfig, use_pallas: bool):
    """One adaLN-zero DiT block. x: [B,T,D], c: [B,D], bp: per-layer params."""
    B, T, D = x.shape
    H, Dh = cfg.heads, cfg.head_dim
    mod = jax.nn.silu(c) @ bp["blk_adaln_w"] + bp["blk_adaln_b"]
    (sh1, s1, g1, sh2, s2, g2) = jnp.split(mod, 6, axis=-1)
    # attention branch
    h = _modulate(_ln(x), sh1, s1)
    qkv = h @ bp["blk_qkv_w"] + bp["blk_qkv_b"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    o = _mha_dispatch(q, k, v, use_pallas)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, D)
    x = x + g1[:, None, :] * (o @ bp["blk_proj_w"] + bp["blk_proj_b"])
    # MLP branch
    h = _modulate(_ln(x), sh2, s2)
    h = jax.nn.silu(h @ bp["blk_mlp_w1"] + bp["blk_mlp_b1"])
    x = x + g2[:, None, :] * (h @ bp["blk_mlp_w2"] + bp["blk_mlp_b2"])
    return x


def _block_params_at(p: Dict, layer):
    """Dynamic per-layer slice of the stacked block weights (runtime index)."""
    return {n: jax.lax.dynamic_index_in_dim(p[n], layer, 0, keepdims=False)
            for n in BLOCK_PARAM_NAMES}


def _block_params_static(p: Dict, layer: int):
    return {n: p[n][layer] for n in BLOCK_PARAM_NAMES}


def patchify(x_flat, cfg: ModelConfig):
    """[B, frames·C·H·W] -> token patches [B, T, patch_dim]."""
    B = x_flat.shape[0]
    F, C, H, W, P = cfg.frames, cfg.channels, cfg.image_size, cfg.image_size, cfg.patch
    x = x_flat.reshape(B, F, C, H // P, P, W // P, P)
    x = x.transpose(0, 1, 3, 5, 4, 6, 2)           # B,F,h,w,P,P,C
    return x.reshape(B, cfg.tokens, cfg.patch_dim)


def unpatchify(tok, cfg: ModelConfig):
    """[B, T, patch_dim] -> [B, frames·C·H·W]."""
    B = tok.shape[0]
    F, C, H, W, P = cfg.frames, cfg.channels, cfg.image_size, cfg.image_size, cfg.patch
    x = tok.reshape(B, F, H // P, W // P, P, P, C)
    x = x.transpose(0, 1, 6, 2, 4, 3, 5)           # B,F,C,h,P,w,P
    return x.reshape(B, F * C * H * W)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def embed_tokens(p: Dict, x_flat, cfg: ModelConfig):
    return patchify(x_flat, cfg) @ p["patch_w"] + p["patch_b"] + p["pos_emb"][None]


def head(p: Dict, x, c):
    """Final adaLN + linear projection of token features. -> [B,T,patch_dim]."""
    mod = jax.nn.silu(c) @ p["head_adaln_w"] + p["head_adaln_b"]
    shift, scale = jnp.split(mod, 2, axis=-1)
    h = _ln(x) * (1.0 + scale[:, None, :]) + shift[:, None, :]
    return h @ p["head_w"] + p["head_b"]


def full_fwd(p: Dict, x_flat, t, y, cfg: ModelConfig, use_pallas: bool = False,
             unroll: bool = False):
    """Complete forward pass. Returns (eps[B,F_lat], boundaries[L+1,B,T,D]).

    boundaries[i] is the input to block i; boundaries[L] is the last block's
    output (the head input) — the tap points the TaylorSeer cache tracks.
    """
    c = cond_embed(p, t, y, cfg)
    x0 = embed_tokens(p, x_flat, cfg)
    if unroll:
        feats = [x0]
        xc = x0
        for l in range(cfg.depth):
            xc = dit_block(_block_params_static(p, l), xc, c, cfg, use_pallas)
            feats.append(xc)
        xL = xc
        boundaries = jnp.stack(feats)
    else:
        stacked = {n: p[n] for n in BLOCK_PARAM_NAMES}

        def body(xc, bp):
            xn = dit_block(bp, xc, c, cfg, use_pallas)
            return xn, xn

        xL, outs = jax.lax.scan(body, x0, stacked)
        boundaries = jnp.concatenate([x0[None], outs], axis=0)
    eps = unpatchify(head(p, xL, c), cfg)
    return eps, boundaries


def block_fwd(p: Dict, layer, feat, t, y, cfg: ModelConfig, use_pallas: bool = False):
    """Verification entry point: run block ``layer`` (runtime i32) on
    ``feat`` (the draft-predicted input). Cost ≈ full_fwd / depth."""
    c = cond_embed(p, t, y, cfg)
    return dit_block(_block_params_at(p, layer), feat, c, cfg, use_pallas)


def head_fwd(p: Dict, feat, t, y, cfg: ModelConfig):
    """Speculative-step output path: predicted last boundary -> eps."""
    c = cond_embed(p, t, y, cfg)
    return unpatchify(head(p, feat, c), cfg)


# ---------------------------------------------------------------------------
# Tiny MLP classifier (FID features + Inception-style score; build-time
# trained, exported for the Rust metrics pipeline)
# ---------------------------------------------------------------------------

CLS_PARAM_NAMES = ["c_w1", "c_b1", "c_w2", "c_b2", "c_w3", "c_b3"]


def cls_param_shapes(latent_dim: int, hidden: int, feat_dim: int, classes: int):
    return {
        "c_w1": (latent_dim, hidden), "c_b1": (hidden,),
        "c_w2": (hidden, feat_dim), "c_b2": (feat_dim,),
        "c_w3": (feat_dim, classes), "c_b3": (classes,),
    }


def cls_init(latent_dim, hidden, feat_dim, classes, key):
    shapes = cls_param_shapes(latent_dim, hidden, feat_dim, classes)
    out = {}
    for name, k in zip(CLS_PARAM_NAMES, jax.random.split(key, len(CLS_PARAM_NAMES))):
        shp = shapes[name]
        if name.endswith(("b1", "b2", "b3")):
            out[name] = jnp.zeros(shp, jnp.float32)
        else:
            out[name] = jax.random.normal(k, shp, jnp.float32) / math.sqrt(shp[0])
    return out


def cls_fwd(p: Dict, x_flat):
    """x: [B, latent] -> (logits [B,K], features [B,feat_dim])."""
    h = jnp.tanh(x_flat @ p["c_w1"] + p["c_b1"])
    f = jnp.tanh(h @ p["c_w2"] + p["c_b2"])
    return f @ p["c_w3"] + p["c_b3"], f
