"""L1 Pallas kernel: tiled multi-head attention (the DiT hot-spot).

TPU adaptation of the paper's CUDA attention (DESIGN.md §3): instead of a
threadblock-per-tile schedule into shared memory, the BlockSpec grid
expresses the HBM→VMEM pipeline — one (batch·head, q-block) program per
grid step, with an online-softmax (running max / running sum) loop over
k/v blocks so the working set per program stays VMEM-resident:

    VMEM bytes ≈ 4 · (blk_q·Dh  +  2·blk_k·Dh  +  blk_q·blk_k  +  2·blk_q)

MXU work is the two tile matmuls (blk_q×Dh)·(Dh×blk_k) and
(blk_q×blk_k)·(blk_k×Dh) with f32 accumulation.

``interpret=True`` is mandatory on this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the kernel lowers through the pallas
interpreter into plain HLO (loops + elementwise + dot), which both pytest
and the Rust runtime execute. Structure (tiling/fusion/single-pass) is what
we optimize; real-TPU perf is estimated in DESIGN.md §9.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _mha_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_k: int, scale: float):
    """One program = one (batch·head, q-block). Online softmax over k/v."""
    q = q_ref[...].astype(jnp.float32) * scale          # [blk_q, dh]
    blk_q, dh = q.shape
    kv_len = k_ref.shape[0]
    n_kv = kv_len // blk_k

    def body(i, carry):
        acc, m_i, l_i = carry
        k = k_ref[pl.dslice(i * blk_k, blk_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(i * blk_k, blk_k), :].astype(jnp.float32)
        s = q @ k.T                                      # [blk_q, blk_k]
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((blk_q, dh), jnp.float32)
    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[...] = (acc / l_i[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_q", "blk_k"))
def mha(q, k, v, blk_q: int = 32, blk_k: int = 32):
    """Pallas multi-head attention. q,k,v: [B, H, T, Dh] -> [B, H, T, Dh].

    Token count T must be divisible by the block sizes (the DiT token grids
    here are powers of two; block sizes are clamped to T).
    """
    b, h, t, dh = q.shape
    blk_q = min(blk_q, t)
    blk_k = min(blk_k, t)
    assert t % blk_q == 0 and t % blk_k == 0, (t, blk_q, blk_k)
    scale = 1.0 / math.sqrt(dh)

    qf = q.reshape(b * h, t, dh)
    kf = k.reshape(b * h, t, dh)
    vf = v.reshape(b * h, t, dh)

    grid = (b * h, t // blk_q)
    out = pl.pallas_call(
        functools.partial(_mha_kernel, blk_k=blk_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, blk_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, t, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, blk_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, t, dh)


def vmem_bytes(blk_q: int, blk_k: int, dh: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one program (DESIGN.md §9)."""
    return dtype_bytes * (blk_q * dh + 2 * blk_k * dh + blk_q * blk_k + 2 * blk_q)


def mxu_utilization_estimate(t: int, dh: int, blk_q: int, blk_k: int) -> float:
    """Fraction of MXU 128×128 tile MACs doing useful work for this shape."""
    def eff(m, n, kk):
        pads = lambda x: 128 * math.ceil(x / 128)
        return (m * n * kk) / (pads(m) * pads(n) * pads(kk))
    # two matmuls per kv block: (blk_q×dh)·(dh×blk_k), (blk_q×blk_k)·(blk_k×dh)
    return 0.5 * (eff(blk_q, blk_k, dh) + eff(blk_q, dh, blk_k))
