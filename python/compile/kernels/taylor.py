"""L1 Pallas kernels: TaylorSeer draft model (paper §3.3, Eq. 2-3).

Two kernels over flattened feature vectors:

* ``taylor_predict`` — Horner-style evaluation of the truncated Taylor
  series F + Σ Δ^i F · (k/N)^i / i! over a stack of backward differences.
  Blocked along the feature axis so each grid step streams one VMEM-sized
  tile of every order; VPU-bound FMA chain (the paper's C_pred ≪ C).
* ``taylor_update`` — rolling backward-difference refresh when a full
  computation lands: Δ^0 ← F_new, Δ^i ← Δ^{i-1}_new − Δ^{i-1}_old.

Runtime scalars (k, N) enter as a length-2 f32 operand so one compiled
artifact serves every speculative offset.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_blk(f: int, blk: int) -> int:
    """Largest block <= blk that divides f (power-of-two preferred)."""
    blk = min(blk, f)
    while f % blk:
        blk -= 1
    return blk


def _predict_kernel(kn_ref, f_ref, o_ref, *, m1: int):
    kn = kn_ref[...]
    ratio = kn[0] / kn[1]                       # k / N
    # Horner: acc = Δ^m/m!; acc = acc*(ratio/ i) ... evaluate explicitly to
    # keep coefficients exact: c_i = ratio^i / i!.
    acc = f_ref[m1 - 1, :] * (1.0 / math.factorial(m1 - 1))
    for i in range(m1 - 2, -1, -1):
        acc = acc * ratio + f_ref[i, :] * (1.0 / math.factorial(i))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("blk",))
def taylor_predict(factors, k, interval, blk: int = 4096):
    """factors: [m+1, F]; k, interval: scalars -> predicted feature [F]."""
    m1, f = factors.shape
    blk = pick_blk(f, blk)
    kn = jnp.stack([jnp.asarray(k, jnp.float32), jnp.asarray(interval, jnp.float32)])
    return pl.pallas_call(
        functools.partial(_predict_kernel, m1=m1),
        grid=(f // blk,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((m1, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((f,), factors.dtype),
        interpret=True,
    )(kn, factors)


def _update_kernel(f_ref, new_ref, o_ref, *, m1: int):
    prev = new_ref[...]
    o_ref[0, :] = prev
    for i in range(1, m1):
        cur = prev - f_ref[i - 1, :]
        o_ref[i, :] = cur
        prev = cur


@functools.partial(jax.jit, static_argnames=("blk",))
def taylor_update(factors, feat, blk: int = 4096):
    """factors: [m+1, F] old differences; feat: [F] fresh feature -> [m+1, F]."""
    m1, f = factors.shape
    blk = pick_blk(f, blk)
    return pl.pallas_call(
        functools.partial(_update_kernel, m1=m1),
        grid=(f // blk,),
        in_specs=[
            pl.BlockSpec((m1, blk), lambda i: (0, i)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((m1, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m1, f), factors.dtype),
        interpret=True,
    )(factors, feat)
