"""L1 Pallas kernels: sampler state updates.

* ``ddim_step`` — deterministic DDIM (η=0) latent update given ε̂ and the
  (ᾱ_t, ᾱ_prev) pair for the current schedule position.
* ``rf_step``   — rectified-flow Euler step given the velocity prediction.

Both are elementwise over the latent; blocked so one VMEM tile of x and
eps is live per grid step, with the scalar schedule constants passed as a
tiny operand (one compiled artifact serves every timestep).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ddim_kernel(ab_ref, x_ref, e_ref, o_ref):
    ab = ab_ref[...]
    ab_t, ab_prev = ab[0], ab[1]
    x = x_ref[...]
    e = e_ref[...]
    x0 = (x - jnp.sqrt(1.0 - ab_t) * e) * jax.lax.rsqrt(ab_t)
    o_ref[...] = jnp.sqrt(ab_prev) * x0 + jnp.sqrt(1.0 - ab_prev) * e


def ddim_step(x, eps, ab_t, ab_prev, blk: int = 4096):
    """x, eps: [F] flattened latent; ab_*: scalars -> x_{t-1} [F]."""
    f = x.shape[0]
    from .taylor import pick_blk
    blk = pick_blk(f, blk)
    ab = jnp.stack([jnp.asarray(ab_t, jnp.float32), jnp.asarray(ab_prev, jnp.float32)])
    return pl.pallas_call(
        _ddim_kernel,
        grid=(f // blk,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((f,), x.dtype),
        interpret=True,
    )(ab, x, eps)


def _rf_kernel(dt_ref, x_ref, v_ref, o_ref):
    o_ref[...] = x_ref[...] - dt_ref[0] * v_ref[...]


def rf_step(x, v, dt, blk: int = 4096):
    """x, v: [F]; dt scalar -> x − dt·v."""
    f = x.shape[0]
    from .taylor import pick_blk
    blk = pick_blk(f, blk)
    dtv = jnp.asarray([dt], jnp.float32)
    return pl.pallas_call(
        _rf_kernel,
        grid=(f // blk,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((f,), x.dtype),
        interpret=True,
    )(dtv, x, v)
