"""L1 Pallas kernel: fused verification norms (paper §3.4 Eq. 4, App. E).

The acceptance test needs e = ‖pred − actual‖₂ / (‖actual‖₂ + ε). A naive
implementation reads both operands twice (diff-norm pass + norm pass); this
kernel computes all partial sums in a single blocked pass — one HBM read of
each operand — accumulating into a tiny SMEM-resident output across the
sequential grid. Also emits the ℓ1 / ℓ∞ / dot statistics so every error
metric of the Appendix-E ablation comes from the same single pass:

    out = [Σd², Σa², Σ|d|, Σ|a|, max|d|, max|a|, Σp·a, Σp²]
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_STATS = 8


def _verify_kernel(p_ref, a_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros((N_STATS,), jnp.float32)

    p = p_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    d = p - a
    o_ref[0] += jnp.sum(d * d)
    o_ref[1] += jnp.sum(a * a)
    o_ref[2] += jnp.sum(jnp.abs(d))
    o_ref[3] += jnp.sum(jnp.abs(a))
    o_ref[4] = jnp.maximum(o_ref[4], jnp.max(jnp.abs(d)))
    o_ref[5] = jnp.maximum(o_ref[5], jnp.max(jnp.abs(a)))
    o_ref[6] += jnp.sum(p * a)
    o_ref[7] += jnp.sum(p * p)


def verify_stats(pred, actual, blk: int = 4096):
    """pred, actual: [F] -> stats [8] (see module docstring)."""
    f = pred.shape[0]
    from .taylor import pick_blk
    blk = pick_blk(f, blk)
    return pl.pallas_call(
        _verify_kernel,
        grid=(f // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((N_STATS,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((N_STATS,), jnp.float32),
        interpret=True,
    )(pred, actual)


def rel_l2(pred, actual, eps=1e-8):
    s = verify_stats(pred, actual)
    return jnp.sqrt(s[0]) / (jnp.sqrt(s[1]) + eps)


def rel_l1(pred, actual, eps=1e-8):
    s = verify_stats(pred, actual)
    return s[2] / (s[3] + eps)


def rel_linf(pred, actual, eps=1e-8):
    s = verify_stats(pred, actual)
    return s[4] / (s[5] + eps)


def cosine_err(pred, actual, eps=1e-8):
    s = verify_stats(pred, actual)
    return 1.0 - s[6] / (jnp.sqrt(s[7]) * jnp.sqrt(s[1]) + eps)
