"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``*_ref`` function is the mathematical definition the corresponding
kernel in attention.py / taylor.py / verify.py / ddim.py must reproduce to
float32 tolerance. pytest (python/tests) sweeps shapes and parameters with
hypothesis and asserts allclose.
"""

import math

import jax.numpy as jnp
from jax.scipy.special import logsumexp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def mha_ref(q, k, v, scale=None):
    """Multi-head attention. q,k,v: [B, H, T, Dh] -> [B, H, T, Dh]."""
    dh = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    probs = jnp.exp(logits - logsumexp(logits, axis=-1, keepdims=True))
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


# ---------------------------------------------------------------------------
# TaylorSeer draft model (paper §3.3, Eq. 2-3)
# ---------------------------------------------------------------------------

def taylor_update_ref(factors, feat):
    """Shift in a new fully-computed feature and rebuild finite differences.

    ``factors``: [m+1, F] raw backward differences Δ^i F at the previous
    refresh point. ``feat``: [F] freshly computed feature. Returns the new
    [m+1, F] stack:  Δ^0 = feat,  Δ^i_new = Δ^{i-1}_new − Δ^{i-1}_old.
    This is the standard rolling backward-difference update realizing the
    paper's Eq. 3 once ``m+1`` refresh points have been observed.
    """
    m1 = factors.shape[0]
    out = [feat]
    for i in range(1, m1):
        out.append(out[i - 1] - factors[i - 1])
    return jnp.stack(out)


def taylor_predict_ref(factors, k, interval):
    """Paper Eq. 2: F_pred = Σ_{i=0..m} Δ^i F / (i! · N^i) · (−k)^i.

    With backward differences at spacing N and forward extrapolation by k
    steps from the newest refresh point the signs cancel: coefficient is
    (k/N)^i / i!.
    """
    m1 = factors.shape[0]
    acc = jnp.zeros_like(factors[0])
    for i in range(m1):
        c = (float(k) ** i) / (math.factorial(i) * (float(interval) ** i))
        acc = acc + factors[i] * c
    return acc


def adams_bashforth_predict_ref(history, k, interval):
    """Two-point linear-multistep draft used in the Table-7 ablation.

    ``history``: [2, F] features at the last two refresh points (newest
    first), spaced ``interval`` apart. AB2 with equal steps collapses to
    F + k·(F − F_prev)/N.
    """
    f_new, f_old = history[0], history[1]
    return f_new + (float(k) / float(interval)) * (f_new - f_old)


# ---------------------------------------------------------------------------
# Verification error norms (paper §3.4 Eq. 4 + Appendix E ablations)
# ---------------------------------------------------------------------------

def verify_norms_ref(pred, actual):
    """Returns [‖pred−actual‖₂, ‖actual‖₂] (single conceptual pass)."""
    d = pred - actual
    return jnp.stack([jnp.sqrt(jnp.sum(d * d)), jnp.sqrt(jnp.sum(actual * actual))])


def rel_l2_ref(pred, actual, eps=1e-8):
    n = verify_norms_ref(pred, actual)
    return n[0] / (n[1] + eps)


def rel_l1_ref(pred, actual, eps=1e-8):
    return jnp.sum(jnp.abs(pred - actual)) / (jnp.sum(jnp.abs(actual)) + eps)


def rel_linf_ref(pred, actual, eps=1e-8):
    return jnp.max(jnp.abs(pred - actual)) / (jnp.max(jnp.abs(actual)) + eps)


def cosine_err_ref(pred, actual, eps=1e-8):
    num = jnp.sum(pred * actual)
    den = jnp.sqrt(jnp.sum(pred * pred)) * jnp.sqrt(jnp.sum(actual * actual)) + eps
    return 1.0 - num / den


# ---------------------------------------------------------------------------
# Sampler updates
# ---------------------------------------------------------------------------

def ddim_step_ref(x, eps, ab_t, ab_prev):
    """Deterministic DDIM (η=0): x_{t-1} from x_t and ε̂."""
    x0 = (x - jnp.sqrt(1.0 - ab_t) * eps) / jnp.sqrt(ab_t)
    return jnp.sqrt(ab_prev) * x0 + jnp.sqrt(1.0 - ab_prev) * eps


def rf_step_ref(x, v, dt):
    """Rectified-flow Euler step toward data: x ← x − dt·v (v ≙ x1 − x0)."""
    return x - dt * v
